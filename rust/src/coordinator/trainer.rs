//! The training coordinator: drives runtime + sampler + data through the
//! paper's Alg. 1 loop, with full cost accounting.
//!
//! Per active step (batch-level methods):
//!   1. draw a uniform meta-batch B from the kept set           [data]
//!   2. scoring FP over B at the latest parameters              [scoring_fp]
//!   3. sampler.observe_meta — the Eq. 3.1 state update         [select]
//!   4. sampler.select — draw b ⊂ B, probability ∝ w            [select]
//!   5. train_step on b (optionally chunked into micro-batches) [train_bp]
//!   6. sampler.observe_train — free losses from the BP batch   [select]
//!
//! Set-level methods skip 2–4 (select returns the whole meta-batch with
//! per-sample gradient weights) and prune in `on_epoch_start`. Annealing
//! epochs run the standard loop.
//!
//! Gradient accumulation (`micro_batch > 0`) chunks the selected batch
//! into micro-batches executed as sequential optimizer steps — time-exact
//! for the paper's low-resource accounting (#BP passes = ceil(|b|/micro)),
//! and a standard small-scale approximation of true gradient accumulation
//! (documented in DESIGN.md §3).
//!
//! Data-parallel simulation (`workers > 1`): the kept set is sharded
//! round-robin across W simulated workers which take turns stepping; each
//! worker's loss observations are buffered locally and merged into the
//! sampler at epoch boundaries — the paper's "additional round of
//! synchronization" for ESWP pre-training (§D.5). Wall-clock is measured
//! sequentially and reported both raw and /W (ideal scaling).

use crate::config::RunConfig;
use crate::data::loader::EpochLoader;
use crate::data::SplitDataset;
use crate::runtime::{BatchBuf, ModelRuntime};
use crate::sampler::{self, Sampler};
use crate::util::timer::{phase, PhaseTimers};
use crate::util::Pcg64;

use super::accounting::CostSummary;

#[derive(Clone, Debug, Default)]
pub struct EvalStats {
    pub loss: f64,
    pub accuracy: f64,
}

/// Everything one training run produces (one trial).
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub name: String,
    pub sampler: String,
    pub seed: u64,
    pub epochs: usize,
    pub steps: u64,
    /// Mean train loss per epoch (the Fig. 3-style curve).
    pub loss_curve: Vec<f64>,
    /// (epoch, eval loss, eval accuracy) at each eval point.
    pub eval_curve: Vec<(usize, f64, f64)>,
    pub final_eval: EvalStats,
    pub timers: PhaseTimers,
    pub cost: CostSummary,
    /// BP sample count per class (Fig. 9) — classification tasks only.
    pub class_bp_counts: Vec<u64>,
    /// Cumulative BP samples at each eval point (Fig. 10 x-axis).
    pub bp_at_eval: Vec<u64>,
}

impl TrainResult {
    pub fn accuracy_pct(&self) -> f64 {
        100.0 * self.final_eval.accuracy
    }
}

/// Train with a sampler built from the config (fresh state).
pub fn train(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
) -> anyhow::Result<TrainResult> {
    cfg.validate().map_err(|e| anyhow::anyhow!("config: {e}"))?;
    let sampler = sampler::build(&cfg.sampler, data.train.n, cfg.epochs);
    train_with_sampler(cfg, rt, data, sampler)
}

/// Train with an externally-constructed sampler (ablations, tests).
pub fn train_with_sampler(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
    mut sampler: Box<dyn Sampler>,
) -> anyhow::Result<TrainResult> {
    let mut rng = Pcg64::new(cfg.seed);
    rt.init(cfg.seed as i32)?;

    let mut timers = PhaseTimers::new();
    let mut meta_buf = BatchBuf::new();
    let mut mini_buf = BatchBuf::new();
    let train_ds = &data.train;
    let n = train_ds.n;
    let classes = train_ds.classes.max(1);
    let mut class_bp_counts = vec![0u64; classes];

    // LR horizon: full-data steps so every method sees the same schedule
    // (pruning shortens the run, not the schedule — matches InfoBatch).
    let total_steps = cfg.epochs * n.div_ceil(cfg.meta_batch);
    let mut step_idx = 0usize;

    let mut fp_samples = 0u64;
    let mut bp_samples = 0u64;
    let mut bp_passes = 0u64;
    let mut steps = 0u64;
    let mut loss_curve = Vec::with_capacity(cfg.epochs);
    let mut eval_curve = Vec::new();
    let mut bp_at_eval = Vec::new();

    let workers = cfg.workers.max(1);

    for epoch in 0..cfg.epochs {
        // ---- set-level selection -------------------------------------
        let kept = timers.time(phase::PRUNE, || sampler.on_epoch_start(epoch, &mut rng));
        anyhow::ensure!(!kept.is_empty(), "sampler kept nothing at epoch {epoch}");

        // ---- build per-worker loaders ---------------------------------
        let mut loaders: Vec<EpochLoader> = if workers == 1 {
            vec![EpochLoader::new(&kept, cfg.meta_batch, &mut rng)]
        } else {
            // Shard round-robin; every worker sees a disjoint subset.
            (0..workers)
                .map(|w| {
                    let shard: Vec<u32> =
                        kept.iter().copied().skip(w).step_by(workers).collect();
                    let shard = if shard.is_empty() { kept.clone() } else { shard };
                    let mut wrng = rng.fork(0xd15c0 + w as u64);
                    EpochLoader::new(&shard, cfg.meta_batch, &mut wrng)
                })
                .collect()
        };
        // Deferred sampler observations per worker (distributed sim).
        let mut sync_buf: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();

        let mut epoch_loss_sum = 0.0f64;
        let mut epoch_loss_cnt = 0u64;

        // ---- step loop: round-robin across workers --------------------
        'rounds: loop {
            let mut progressed = false;
            for w in 0..workers {
                let Some(meta) = loaders[w].next_batch() else { continue };
                progressed = true;

                timers.time(phase::DATA, || meta_buf.fill(train_ds, &meta));

                // Scoring FP (batch-level methods during active epochs).
                let selecting = cfg.mini_batch < cfg.meta_batch;
                if selecting && sampler.needs_meta_losses(epoch) {
                    let losses = timers.time(phase::SCORING_FP, || {
                        rt.loss_fwd(meta_buf.x(train_ds), &meta_buf.y, meta.len())
                    })?;
                    fp_samples += meta.len() as u64;
                    if workers == 1 {
                        timers.time(phase::SELECT, || {
                            sampler.observe_meta(&meta, &losses, epoch)
                        });
                    } else {
                        // Distributed: defer to the sync round, but still
                        // feed this worker's local view for selection.
                        sampler.observe_meta(&meta, &losses, epoch);
                        sync_buf.push((meta.clone(), losses));
                    }
                }

                let sel = timers.time(phase::SELECT, || {
                    sampler.select(&meta, cfg.mini_batch, epoch, &mut rng)
                });
                debug_assert!(!sel.indices.is_empty());

                // Assemble the BP batch (reuse the meta buffer when the
                // selection is the identity — the common set-level path).
                let bsz = sel.indices.len();
                let (buf, y_ref): (&BatchBuf, &Vec<i32>) = if sel.indices == meta {
                    (&meta_buf, &meta_buf.y)
                } else {
                    timers.time(phase::DATA, || mini_buf.fill(train_ds, &sel.indices));
                    (&mini_buf, &mini_buf.y)
                };

                let lr = cfg.lr.lr_at(step_idx, total_steps) as f32;

                // Gradient accumulation: chunk into micro-batches.
                let micro = if cfg.micro_batch > 0 && cfg.micro_batch < bsz {
                    cfg.micro_batch
                } else {
                    bsz
                };
                let mut all_losses = Vec::with_capacity(bsz);
                let mut mean_acc = 0.0f64;
                let mut off = 0usize;
                let x_len = train_ds.x_len();
                let y_len = train_ds.y_dim;
                while off < bsz {
                    let m = micro.min(bsz - off);
                    let out = timers.time(phase::TRAIN_BP, || {
                        let x = match buf.x(train_ds) {
                            crate::runtime::BatchX::F32(v) => crate::runtime::BatchX::F32(
                                &v[off * x_len..(off + m) * x_len],
                            ),
                            crate::runtime::BatchX::I32(v) => crate::runtime::BatchX::I32(
                                &v[off * x_len..(off + m) * x_len],
                            ),
                        };
                        rt.train_step(
                            x,
                            &y_ref[off * y_len..(off + m) * y_len],
                            &sel.weights[off..off + m],
                            lr,
                            m,
                        )
                    })?;
                    bp_passes += 1;
                    bp_samples += m as u64;
                    mean_acc += out.mean_loss as f64 * m as f64;
                    all_losses.extend_from_slice(&out.losses);
                    off += m;
                }
                let step_mean = mean_acc / bsz as f64;
                epoch_loss_sum += step_mean;
                epoch_loss_cnt += 1;

                // Per-class BP counts (Fig. 9).
                if train_ds.y_dim == 1 && train_ds.classes > 0 {
                    for &i in &sel.indices {
                        class_bp_counts[train_ds.clean_class[i as usize] as usize] += 1;
                    }
                }

                // Free training losses back to the sampler.
                if workers == 1 {
                    timers.time(phase::SELECT, || {
                        sampler.observe_train(&sel.indices, &all_losses, epoch)
                    });
                } else {
                    sync_buf.push((sel.indices.clone(), all_losses));
                }

                step_idx += 1;
                steps += 1;
            }
            if !progressed {
                break 'rounds;
            }
        }

        // ---- distributed score synchronization ------------------------
        if workers > 1 && !sync_buf.is_empty() {
            timers.time(phase::SELECT, || {
                for (idx, losses) in sync_buf.drain(..) {
                    sampler.observe_train(&idx, &losses, epoch);
                }
            });
        }

        loss_curve.push(if epoch_loss_cnt > 0 {
            epoch_loss_sum / epoch_loss_cnt as f64
        } else {
            f64::NAN
        });

        // ---- eval ------------------------------------------------------
        let at_eval_point = cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0;
        if at_eval_point || epoch + 1 == cfg.epochs {
            let stats = timers.time(phase::EVAL, || evaluate(rt, data))?;
            eval_curve.push((epoch, stats.loss, stats.accuracy));
            bp_at_eval.push(bp_samples);
        }
    }

    let final_eval = eval_curve
        .last()
        .map(|&(_, l, a)| EvalStats { loss: l, accuracy: a })
        .unwrap_or_default();
    let cost = CostSummary::from_run(
        &timers,
        fp_samples,
        bp_samples,
        bp_passes,
        rt.flops_per_sample_fwd(),
    );

    Ok(TrainResult {
        name: cfg.name.clone(),
        sampler: sampler.name().to_string(),
        seed: cfg.seed,
        epochs: cfg.epochs,
        steps,
        loss_curve,
        eval_curve,
        final_eval,
        timers,
        cost,
        class_bp_counts,
        bp_at_eval,
    })
}

/// Evaluate on the held-out set, chunked to the runtime's eval batch size
/// (tail padded by wraparound; pad rows excluded from the averages).
pub fn evaluate(rt: &mut dyn ModelRuntime, data: &SplitDataset) -> anyhow::Result<EvalStats> {
    let ds = &data.test;
    let chunk = if rt.eval_size() > 0 { rt.eval_size() } else { ds.n };
    let mut buf = BatchBuf::new();
    let mut idx = Vec::with_capacity(chunk);
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut count = 0usize;
    let mut off = 0usize;
    while off < ds.n {
        let valid = chunk.min(ds.n - off);
        idx.clear();
        for k in 0..chunk {
            idx.push(((off + k) % ds.n) as u32);
        }
        buf.fill(ds, &idx);
        let (losses, correct) = rt.eval(buf.x(ds), &buf.y, chunk)?;
        for i in 0..valid {
            loss_sum += losses[i] as f64;
            acc_sum += correct[i] as f64;
        }
        count += valid;
        off += valid;
    }
    anyhow::ensure!(count > 0, "empty test set");
    Ok(EvalStats { loss: loss_sum / count as f64, accuracy: acc_sum / count as f64 })
}

/// Run `trials` independent seeds and average the headline numbers.
pub struct TrialSummary {
    pub results: Vec<TrainResult>,
}

impl TrialSummary {
    pub fn mean_accuracy_pct(&self) -> f64 {
        self.results.iter().map(|r| r.accuracy_pct()).sum::<f64>() / self.results.len() as f64
    }

    pub fn mean_eval_loss(&self) -> f64 {
        self.results.iter().map(|r| r.final_eval.loss).sum::<f64>() / self.results.len() as f64
    }

    pub fn mean_train_wall_s(&self) -> f64 {
        self.results.iter().map(|r| r.cost.train_wall_s()).sum::<f64>()
            / self.results.len() as f64
    }

    pub fn total_cost(&self) -> CostSummary {
        // Sum counts across trials (flops ratios are scale-invariant).
        let mut total = CostSummary::default();
        for r in &self.results {
            total.fp_samples += r.cost.fp_samples;
            total.bp_samples += r.cost.bp_samples;
            total.bp_passes += r.cost.bp_passes;
            total.fp_flops += r.cost.fp_flops;
            total.bp_flops += r.cost.bp_flops;
            total.scoring_s += r.cost.scoring_s;
            total.train_s += r.cost.train_s;
            total.select_s += r.cost.select_s;
            total.data_s += r.cost.data_s;
            total.prune_s += r.cost.prune_s;
            total.eval_s += r.cost.eval_s;
        }
        total
    }
}

/// Train `trials` seeds of the same config on a fresh runtime state.
pub fn run_trials(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
    trials: usize,
) -> anyhow::Result<TrialSummary> {
    let mut results = Vec::with_capacity(trials);
    for t in 0..trials {
        let mut c = cfg.clone();
        c.seed = cfg.seed + 1000 * t as u64;
        results.push(train(&c, rt, data)?);
    }
    Ok(TrialSummary { results })
}
