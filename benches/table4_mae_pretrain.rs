//! Regenerates paper Table 4 + Fig. 3 (MAE pre-training, 4-worker sim).
fn main() {
    evosample::experiments::table4::run(evosample::config::presets::Scale::from_env())
        .expect("table4");
}
