//! Regenerates paper Fig. 9 (per-class BP-sample counts under ESWP).
fn main() {
    evosample::experiments::fig9::run(evosample::config::presets::Scale::from_env())
        .expect("fig9");
}
