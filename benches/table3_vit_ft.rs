//! Regenerates paper Table 3 (large-model full fine-tuning).
fn main() {
    evosample::experiments::table3::run(evosample::config::presets::Scale::from_env())
        .expect("table3");
}
