//! Config system: TOML-subset parser, typed schema, experiment presets.

pub mod presets;
pub mod schema;
pub mod toml;

pub use schema::{
    DatasetConfig, LrSchedule, RunConfig, SamplerConfig, ScoringPrecision, ServeConfig,
    TelemetryLevel,
};
pub use toml::Doc;

/// Load a RunConfig from a TOML file path.
pub fn load(path: &str) -> Result<RunConfig, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let doc = Doc::parse(&src)?;
    RunConfig::from_doc(&doc)
}
