//! The TCP front door: accept loop, per-connection request handling,
//! startup rescan, and drain/abort shutdown.
//!
//! The server binds localhost only. Each connection gets its own
//! detached thread speaking the line protocol ([`super::protocol`]);
//! an `events` request flips the connection into streaming mode until
//! the watched job finishes. On startup the state dir is rescanned:
//! jobs left in a non-terminal state by a previous life (killed server,
//! `shutdown abort`) are re-enqueued and resume from their last
//! checkpoint.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::{Doc, RunConfig, ServeConfig};
use crate::runtime::kernel::pool::KernelBudget;
use crate::sampler::registry;
use crate::util::json::{num, obj, s, Json};

use super::job::{self, JobShared, JobState, INTERRUPT_CANCEL};
use super::protocol::{err_response, ok_response, rejected_response, Request};
use super::queue::{JobEntry, JobQueue};
use super::scheduler::{self, SharedQueue};

struct Inner {
    state: SharedQueue,
    budget: Arc<KernelBudget>,
    state_dir: PathBuf,
    stop_accept: AtomicBool,
    next_id: AtomicU64,
    /// Per-connection read timeout (`serve.read_timeout_ms`; None = no
    /// timeout): a client that goes silent mid-request is rejected and
    /// disconnected instead of pinning its connection thread forever.
    read_timeout: Option<Duration>,
}

/// Hard cap on one request line (DESIGN.md §12): a client streaming an
/// unterminated line cannot balloon the connection thread's memory —
/// past this the request is rejected (`line_too_long`) and the
/// connection closed.
const MAX_LINE_BYTES: usize = 1 << 20;

/// The running service. [`Server::start`] returns a handle; `wait`
/// blocks until a `shutdown` request (or [`ServerHandle::shutdown`])
/// stops it.
pub struct Server;

pub struct ServerHandle {
    addr: SocketAddr,
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Boot the service: rescan the state dir, bind
    /// `127.0.0.1:{cfg.port}` (0 = ephemeral), spawn the worker pool
    /// and the accept loop.
    pub fn start(cfg: ServeConfig) -> anyhow::Result<ServerHandle> {
        cfg.validate().map_err(|e| anyhow::anyhow!("serve config: {e}"))?;
        // The service always keeps counters live so the `metrics` verb
        // has something to scrape; jobs may raise further (to trace) but
        // never lower the process level.
        crate::obs::raise_level(crate::obs::COUNTERS);
        let state_dir = PathBuf::from(&cfg.state_dir);
        std::fs::create_dir_all(&state_dir)?;
        let state: SharedQueue =
            Arc::new((Mutex::new(JobQueue::new(cfg.max_queue)), Condvar::new()));
        let budget = KernelBudget::new(cfg.effective_kernel_budget());
        let resumed = rescan(&state_dir, &state);
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        println!("serve: listening on {addr} (budget {} lanes)", budget.total());
        if resumed > 0 {
            println!("serve: re-enqueued {resumed} unfinished job(s) from {}", state_dir.display());
        }
        let workers =
            scheduler::spawn_workers(Arc::clone(&state), Arc::clone(&budget), cfg.clone())?;
        let inner = Arc::new(Inner {
            state,
            budget,
            state_dir,
            stop_accept: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            read_timeout: match cfg.read_timeout_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
        });
        let accept_inner = Arc::clone(&inner);
        let accept = std::thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || accept_loop(listener, accept_inner));
        let accept = match accept {
            Ok(h) => h,
            Err(e) => {
                // Never leave the worker pool orphaned behind a dead
                // front door: shut it down, then surface the error.
                eprintln!("serve: failed to spawn accept thread: {e}");
                initiate_shutdown(&inner, true);
                for w in workers {
                    let _ = w.join();
                }
                return Err(anyhow::anyhow!("failed to spawn accept thread: {e}"));
            }
        };
        Ok(ServerHandle { addr, inner, workers, accept: Some(accept) })
    }
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Programmatic shutdown, equivalent to a `shutdown` request.
    pub fn shutdown(&self, abort: bool) {
        initiate_shutdown(&self.inner, abort);
    }

    /// Block until the service stops (all workers drained/aborted),
    /// then reap the accept thread.
    pub fn wait(mut self) {
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.inner.stop_accept.store(true, Ordering::Relaxed);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }
}

/// Re-enqueue every non-terminal job record left by a previous server
/// life; terminal records stay visible to `status`. Returns the number
/// of re-enqueued jobs.
fn rescan(state_dir: &Path, state: &SharedQueue) -> usize {
    let records = job::scan_records(state_dir);
    let (lock, _) = &**state;
    let mut q = lock.lock().unwrap_or_else(|e| e.into_inner());
    let mut resumed = 0;
    for rec in records {
        let cfg = match Doc::parse(&rec.config_toml).and_then(|d| RunConfig::from_doc(&d)) {
            Ok(cfg) => cfg,
            Err(_) => continue, // unusable record; leave the file for inspection
        };
        let shared = Arc::new(
            JobShared::new(&rec.id, &cfg.name, cfg.sampler.name(), cfg.epochs)
                .with_record(&rec),
        );
        if rec.state.is_terminal() {
            shared.restore_terminal(rec.state);
            let entry =
                JobEntry { cfg, config_toml: rec.config_toml, shared, has_checkpoint: false };
            q.insert_terminal(&rec.id, entry);
            continue;
        }
        shared.push_event(obj(vec![("event", s("requeued")), ("after", s(rec.state.as_str()))]));
        let has_checkpoint = state_dir.join(format!("{}.ckpt", rec.id)).exists();
        let entry = JobEntry { cfg, config_toml: rec.config_toml, shared, has_checkpoint };
        q.requeue(&rec.id, entry);
        resumed += 1;
    }
    resumed
}

fn initiate_shutdown(inner: &Inner, abort: bool) {
    inner.stop_accept.store(true, Ordering::Relaxed);
    let (lock, cvar) = &*inner.state;
    lock.lock().unwrap_or_else(|e| e.into_inner()).begin_shutdown(abort);
    cvar.notify_all();
}

fn accept_loop(listener: TcpListener, inner: Arc<Inner>) {
    loop {
        if inner.stop_accept.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || {
                    let _ = handle_connection(stream, inner);
                });
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(15));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(15)),
        }
    }
}

fn write_line(out: &mut TcpStream, j: &Json) -> std::io::Result<()> {
    crate::fault::hit_io(crate::fault::sites::SERVE_SOCKET_WRITE)?;
    out.write_all(j.to_string_compact().as_bytes())?;
    out.write_all(b"\n")
}

/// What one bounded line read produced.
enum LineRead {
    /// A complete request line (terminator stripped) is in the buffer.
    Line,
    /// Clean end of stream before any byte.
    Eof,
    /// The line exceeded [`MAX_LINE_BYTES`]; the rest is unread.
    TooLong,
}

/// Read one `\n`-terminated line of at most `cap` content bytes into
/// `buf` — the bounded replacement for `BufRead::lines()`, which would
/// buffer an unterminated line without limit. A final unterminated line
/// (EOF mid-line) still parses; non-UTF-8 input fails with
/// `InvalidData`; read timeouts surface as the platform's
/// `WouldBlock`/`TimedOut`.
fn read_bounded_line(
    reader: &mut impl BufRead,
    buf: &mut String,
    cap: usize,
) -> std::io::Result<LineRead> {
    crate::fault::hit_io(crate::fault::sites::SERVE_SOCKET_READ)?;
    let mut bytes: Vec<u8> = Vec::new();
    loop {
        let (take, found_nl, eof) = {
            let chunk = match reader.fill_buf() {
                Ok(c) => c,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if chunk.is_empty() {
                (0, false, true)
            } else {
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        if bytes.len() + pos > cap {
                            return Ok(LineRead::TooLong);
                        }
                        bytes.extend_from_slice(&chunk[..pos]);
                        (pos + 1, true, false)
                    }
                    None => {
                        if bytes.len() + chunk.len() > cap {
                            return Ok(LineRead::TooLong);
                        }
                        bytes.extend_from_slice(chunk);
                        (chunk.len(), false, false)
                    }
                }
            }
        };
        if eof {
            if bytes.is_empty() {
                return Ok(LineRead::Eof);
            }
            break;
        }
        reader.consume(take);
        if found_nl {
            break;
        }
    }
    match String::from_utf8(bytes) {
        Ok(text) => {
            buf.push_str(&text);
            Ok(LineRead::Line)
        }
        Err(_) => Err(std::io::Error::new(ErrorKind::InvalidData, "request is not UTF-8")),
    }
}

fn handle_connection(stream: TcpStream, inner: Arc<Inner>) -> std::io::Result<()> {
    if let Some(t) = inner.read_timeout {
        let _ = stream.set_read_timeout(Some(t));
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        let read = match read_bounded_line(&mut reader, &mut line, MAX_LINE_BYTES) {
            Ok(r) => r,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                let _ = write_line(&mut out, &rejected_response("read_timeout"));
                return Ok(());
            }
            Err(e) if e.kind() == ErrorKind::InvalidData => {
                let _ = write_line(&mut out, &err_response("request is not UTF-8"));
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        match read {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => {
                let _ = write_line(&mut out, &rejected_response("line_too_long"));
                return Ok(());
            }
            LineRead::Line => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        let req = match Request::parse(&line) {
            Ok(req) => req,
            Err(e) => {
                write_line(&mut out, &err_response(&e))?;
                continue;
            }
        };
        match req {
            Request::Submit { config_toml, name, sampler, job_id } => {
                let resp = handle_submit(&inner, config_toml, name, sampler, job_id);
                write_line(&mut out, &resp)?;
            }
            Request::Status { job } => {
                write_line(&mut out, &handle_status(&inner, job.as_deref()))?;
            }
            Request::Events { job } => handle_events(&inner, &mut out, &job)?,
            Request::Cancel { job } => {
                write_line(&mut out, &handle_cancel(&inner, &job))?;
            }
            Request::Metrics { job } => {
                write_line(&mut out, &handle_metrics(&inner, job.as_deref()))?;
            }
            Request::Shutdown { abort } => {
                let mode = if abort { "abort" } else { "drain" };
                write_line(&mut out, &ok_response(vec![("shutdown", s(mode))]))?;
                initiate_shutdown(&inner, abort);
                return Ok(());
            }
        }
    }
}

fn handle_submit(
    inner: &Inner,
    config_toml: String,
    name: Option<String>,
    sampler: Option<String>,
    job_id: Option<String>,
) -> Json {
    let doc = match Doc::parse(&config_toml) {
        Ok(doc) => doc,
        Err(e) => return err_response(&format!("config: {e}")),
    };
    let mut cfg = match RunConfig::from_doc(&doc) {
        Ok(cfg) => cfg,
        Err(e) => return err_response(&format!("config: {e}")),
    };
    if let Some(n) = name {
        cfg.name = n;
    }
    if let Some(sname) = sampler {
        match registry::parse(&sname, &registry::ParamBag::new()) {
            Ok(sc) => cfg.sampler = sc,
            Err(e) => return err_response(&format!("sampler: {e}")),
        }
    }
    if let Err(e) = cfg.validate() {
        return err_response(&format!("config: {e}"));
    }
    let id = job_id.unwrap_or_else(|| {
        format!("job-{:x}-{}", std::process::id(), inner.next_id.fetch_add(1, Ordering::Relaxed))
    });
    let legal = |c: char| c.is_ascii_alphanumeric() || c == '-' || c == '_';
    if id.is_empty() || !id.chars().all(legal) {
        return err_response("job_id must be non-empty [A-Za-z0-9_-]");
    }
    let shared = Arc::new(JobShared::new(&id, &cfg.name, cfg.sampler.name(), cfg.epochs));
    let entry = JobEntry {
        cfg,
        config_toml: config_toml.clone(),
        shared: Arc::clone(&shared),
        has_checkpoint: false,
    };
    let (lock, cvar) = &*inner.state;
    let position = {
        let mut q = lock.lock().unwrap_or_else(|e| e.into_inner());
        let position = match q.submit(&id, entry) {
            Ok(pos) => pos,
            Err(reason) => return rejected_response(reason),
        };
        // Stamp the queued event and the initial durable record while
        // still holding the queue lock: workers claim under this same
        // lock, so their running-state record write always happens-after
        // this one (otherwise a fast worker's record could be clobbered
        // by a stale state=queued snapshot).
        shared.push_event(obj(vec![("event", s("queued")), ("position", num(position as f64))]));
        let _ = job::write_record(&inner.state_dir, &shared, &config_toml);
        position
    };
    cvar.notify_one();
    ok_response(vec![
        ("job", s(id)),
        ("state", s("queued")),
        ("position", num(position as f64)),
    ])
}

fn handle_status(inner: &Inner, job: Option<&str>) -> Json {
    let (lock, _) = &*inner.state;
    let q = lock.lock().unwrap_or_else(|e| e.into_inner());
    match job {
        Some(id) => match q.get(id) {
            Some(entry) => ok_response(vec![("jobs", Json::Arr(vec![entry.shared.status_json()]))]),
            None => err_response("unknown job"),
        },
        None => {
            let jobs: Vec<Json> = q.jobs().map(|(_, e)| e.shared.status_json()).collect();
            ok_response(vec![
                ("jobs", Json::Arr(jobs)),
                ("pending", num(q.pending_len() as f64)),
                ("running", num(q.running_len() as f64)),
                ("kernel_budget", num(inner.budget.total() as f64)),
                ("kernel_in_use", num(inner.budget.in_use() as f64)),
                ("shutting_down", Json::Bool(q.shutting_down())),
            ])
        }
    }
}

/// Telemetry scrape (DESIGN.md §11): the process-wide `obs::` registry
/// snapshot plus queue/kernel occupancy, and per-job selection health
/// (`status_json` carries keep rate, fp passes, epoch progress). With a
/// `job` filter only that job's entry is returned; the process/global
/// section is always present so scrapers get a complete picture from
/// one request.
fn handle_metrics(inner: &Inner, job: Option<&str>) -> Json {
    let (lock, _) = &*inner.state;
    let q = lock.lock().unwrap_or_else(|e| e.into_inner());
    let jobs: Vec<Json> = match job {
        Some(id) => match q.get(id) {
            Some(entry) => vec![entry.shared.status_json()],
            None => return err_response("unknown job"),
        },
        None => q.jobs().map(|(_, e)| e.shared.status_json()).collect(),
    };
    let global = obj(vec![
        (
            "queue",
            obj(vec![
                ("pending", num(q.pending_len() as f64)),
                ("running", num(q.running_len() as f64)),
                ("shutting_down", Json::Bool(q.shutting_down())),
            ]),
        ),
        (
            "kernel",
            obj(vec![
                ("budget", num(inner.budget.total() as f64)),
                ("in_use", num(inner.budget.in_use() as f64)),
            ]),
        ),
        ("obs", crate::metrics::obs_snapshot_json()),
    ]);
    ok_response(vec![("global", global), ("jobs", Json::Arr(jobs))])
}

/// Stream the job's backlog + live events; the stream ends when the job
/// finishes (its subscribers are disconnected), after which one final
/// `ok` line reports the terminal state.
fn handle_events(inner: &Inner, out: &mut TcpStream, id: &str) -> std::io::Result<()> {
    let rx = {
        let (lock, _) = &*inner.state;
        let q = lock.lock().unwrap_or_else(|e| e.into_inner());
        q.get(id).map(|entry| entry.shared.subscribe())
    };
    let Some(rx) = rx else {
        return write_line(out, &err_response("unknown job"));
    };
    for ev in rx {
        write_line(out, &ev)?;
    }
    let state = {
        let (lock, _) = &*inner.state;
        let q = lock.lock().unwrap_or_else(|e| e.into_inner());
        q.get(id).map(|entry| entry.shared.state())
    };
    let state = state.map(JobState::as_str).unwrap_or("unknown");
    write_line(out, &ok_response(vec![("job", s(id)), ("state", s(state))]))
}

fn handle_cancel(inner: &Inner, id: &str) -> Json {
    let (lock, _) = &*inner.state;
    let q = lock.lock().unwrap_or_else(|e| e.into_inner());
    let Some(entry) = q.get(id) else {
        return err_response("unknown job");
    };
    match entry.shared.state() {
        JobState::Queued => {
            entry.shared.request_interrupt(INTERRUPT_CANCEL);
            let msg = "cancelled while queued".to_string();
            entry.shared.finish(JobState::Cancelled, None, Some(msg), None);
            let _ = job::write_record(&inner.state_dir, &entry.shared, &entry.config_toml);
            ok_response(vec![("job", s(id)), ("state", s("cancelled"))])
        }
        JobState::Running => {
            // Cooperative: the epoch hook observes the flag at the next
            // epoch boundary and aborts the run.
            entry.shared.request_interrupt(INTERRUPT_CANCEL);
            ok_response(vec![
                ("job", s(id)),
                ("state", s("running")),
                ("cancel_requested", Json::Bool(true)),
            ])
        }
        other => err_response(&format!("job already {}", other.as_str())),
    }
}
