//! Telemetry overhead bench (DESIGN.md §11): step throughput of the
//! same training run at `telemetry = off`, `counters`, and `trace`.
//!
//! Emits machine-readable `BENCH_obs.json` (best wall + steps/s per
//! level, overhead percentages, span/counter sanity) and exits non-zero
//! if the `counters` level costs more than 3% throughput vs `off` — the
//! observability layer's hard perf budget. Also exits non-zero if any
//! level perturbs the loss curve or the sample accounting: telemetry is
//! observational only, bit-for-bit.

use std::time::Instant;

use evosample::coordinator::train_with_sampler;
use evosample::prelude::*;
use evosample::runtime::native::NativeRuntime;
use evosample::util::bench::smoke_mode;
use evosample::util::json::{num, obj, s, Json};

/// Max counters-level throughput overhead vs off, in percent.
const MAX_COUNTERS_OVERHEAD_PCT: f64 = 3.0;

fn main() {
    let (n, epochs, hidden, reps) =
        if smoke_mode() { (2048, 4, 48, 5) } else { (8192, 8, 96, 5) };

    // The busiest single-worker shape: ES with anneal 0 so every step
    // runs the scoring FP, selection, and observation stages — each one
    // an instrumented site, so per-step telemetry cost is maximally
    // visible in the wall-clock.
    let mut cfg = RunConfig::new(
        "perf_obs",
        "native",
        DatasetConfig::SynthCifar { n, classes: 10, label_noise: 0.05, hard_frac: 0.2 },
    );
    cfg.epochs = epochs;
    cfg.meta_batch = 128;
    cfg.mini_batch = 32;
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
    cfg.test_n = 256;
    cfg.sampler = SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.0 };
    let split = data::build(&cfg.dataset, cfg.test_n, 42);

    println!(
        "== telemetry overhead (n={n}, B={}, b={}, hidden={hidden}, {epochs} epochs, \
         best of {reps}) ==",
        cfg.meta_batch, cfg.mini_batch
    );
    println!("{:>9} {:>12} {:>12} {:>10}", "level", "best_wall_s", "steps/s", "steps");

    struct LevelRun {
        name: &'static str,
        best_wall_s: f64,
        steps_per_s: f64,
        steps: u64,
        loss_curve: Vec<f64>,
        fp_samples: u64,
        bp_samples: u64,
    }

    let levels: [(&str, u8); 3] = [
        ("off", evosample::obs::OFF),
        ("counters", evosample::obs::COUNTERS),
        ("trace", evosample::obs::TRACE),
    ];
    let mut runs: Vec<LevelRun> = Vec::new();
    let mut spans_recorded = 0usize;
    let mut counted_steps = 0u64;
    for (name, level) in levels {
        evosample::obs::set_level(level);
        evosample::obs::registry().reset();
        evosample::obs::clear_spans();
        let mut best_wall = f64::INFINITY;
        let mut kept: Option<LevelRun> = None;
        for _ in 0..reps {
            let mut rt = NativeRuntime::new(split.train.x_len(), hidden, 10);
            let sampler = evosample::sampler::build(&cfg.sampler, split.train.n, cfg.epochs)
                .expect(&cfg.name);
            let t0 = Instant::now();
            let r = train_with_sampler(&cfg, &mut rt, &split, sampler).expect(&cfg.name);
            let wall = t0.elapsed().as_secs_f64() - r.cost.eval_s;
            if wall < best_wall {
                best_wall = wall;
                kept = Some(LevelRun {
                    name,
                    best_wall_s: wall,
                    steps_per_s: r.steps as f64 / wall.max(1e-9),
                    steps: r.steps,
                    loss_curve: r.loss_curve.clone(),
                    fp_samples: r.cost.fp_samples,
                    bp_samples: r.cost.bp_samples,
                });
            }
        }
        let run = kept.expect("at least one rep");
        println!(
            "{name:>9} {:>12.3} {:>12.1} {:>10}",
            run.best_wall_s, run.steps_per_s, run.steps
        );
        if level == evosample::obs::COUNTERS {
            counted_steps = evosample::obs::registry().counter("engine.steps").get();
        }
        if level == evosample::obs::TRACE {
            spans_recorded = evosample::obs::span_count();
        }
        runs.push(run);
    }
    evosample::obs::set_level(evosample::obs::OFF);

    let off = &runs[0];
    let overhead_vs_off = |r: &LevelRun| 100.0 * (1.0 - r.steps_per_s / off.steps_per_s);
    let counters_overhead = overhead_vs_off(&runs[1]);
    let trace_overhead = overhead_vs_off(&runs[2]);
    println!(
        "\ncounters overhead {counters_overhead:+.2}%  trace overhead {trace_overhead:+.2}% \
         (budget: counters <= {MAX_COUNTERS_OVERHEAD_PCT}%)"
    );
    println!(
        "sanity: engine.steps counted {counted_steps} over {reps} counters reps, \
         {spans_recorded} spans in the trace ring"
    );

    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            obj(vec![
                ("level", s(r.name)),
                ("best_wall_s", num(r.best_wall_s)),
                ("steps_per_s", num(r.steps_per_s)),
                ("steps", num(r.steps as f64)),
                ("overhead_pct_vs_off", num(overhead_vs_off(r))),
            ])
        })
        .collect();
    let out = obj(vec![
        ("bench", s("perf_obs")),
        ("backend", s("native")),
        ("mode", s(if smoke_mode() { "smoke" } else { "full" })),
        (
            "shape",
            obj(vec![
                ("n", num(n as f64)),
                ("epochs", num(epochs as f64)),
                ("hidden", num(hidden as f64)),
                ("meta_batch", num(cfg.meta_batch as f64)),
                ("mini_batch", num(cfg.mini_batch as f64)),
                ("reps", num(reps as f64)),
            ]),
        ),
        ("levels", Json::Arr(rows)),
        ("counters_overhead_pct", num(counters_overhead)),
        ("trace_overhead_pct", num(trace_overhead)),
        ("spans_recorded", num(spans_recorded as f64)),
        ("guard_threshold_pct", num(MAX_COUNTERS_OVERHEAD_PCT)),
    ]);
    let payload = out.to_string_compact() + "\n";
    std::fs::write("BENCH_obs.json", payload).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json");

    // ---- CI guards ------------------------------------------------------

    // Telemetry must be observational only: identical numerics and
    // sample accounting at every level.
    for r in &runs[1..] {
        if r.loss_curve != off.loss_curve
            || r.fp_samples != off.fp_samples
            || r.bp_samples != off.bp_samples
            || r.steps != off.steps
        {
            eprintln!(
                "FAIL: telemetry level {:?} perturbed the run (loss curve or sample \
                 accounting differs from off) — the §11 never-perturbs contract is broken",
                r.name
            );
            std::process::exit(1);
        }
    }
    // Counters were actually live during the counters reps, and the
    // trace ring actually holds spans — otherwise the overhead numbers
    // measure nothing.
    if counted_steps < off.steps || spans_recorded == 0 {
        eprintln!(
            "FAIL: instrumentation dead during the bench (engine.steps {counted_steps}, \
             spans {spans_recorded}) — overhead numbers are meaningless"
        );
        std::process::exit(1);
    }
    if counters_overhead > MAX_COUNTERS_OVERHEAD_PCT {
        eprintln!(
            "FAIL: counters-level telemetry costs {counters_overhead:.2}% throughput vs off \
             (budget {MAX_COUNTERS_OVERHEAD_PCT}%)"
        );
        std::process::exit(1);
    }
}
