//! The metrics registry: named `Counter`/`Gauge`/`Histogram` handles
//! with an atomic hot path.
//!
//! Handles are `&'static` (the registry leaks one small allocation per
//! distinct name — the metric namespace is a bounded, code-authored
//! set), so call sites may cache them and record lock-free. The name →
//! handle map itself is behind a mutex, but only lookups touch it;
//! `add`/`set`/`record` are plain relaxed atomics.
//!
//! Level gating happens AT THE CALL SITE (`obs::counters_on()` first,
//! then look up + record), not inside the metric ops — so tests and
//! exporters can drive metrics directly, and an `off`-level site pays
//! exactly one relaxed load.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::json::{num, obj, Json};

/// Monotonic event count.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (queue depth, keep rate, …).
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { v: AtomicI64::new(0) }
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Number of histogram buckets. Bucket `i` covers values in
/// `[2^(i-31), 2^(i-30))` — log2-scaled, fixed, spanning ~5e-10 to
/// ~4e9, which holds both sub-microsecond stage durations (seconds) and
/// raw loss values without configuration.
pub const HIST_BUCKETS: usize = 64;

/// Exponent bias: bucket 0's lower bound is `2^-BUCKET_BIAS`.
const BUCKET_BIAS: i32 = 31;

/// Log-scaled histogram: fixed buckets, relaxed-atomic recording, and
/// approximate quantiles from the bucket counts (each bucket reports
/// its geometric midpoint, so quantiles carry at most a √2 factor of
/// bucket-resolution error — plenty for health dashboards).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Running sum in fixed-point micro-units (f64 can't be atomically
    /// added; 1e-6 resolution over u64 is ample for seconds and losses).
    sum_micro: AtomicU64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_micro: AtomicU64::new(0),
        }
    }

    fn bucket_index(v: f64) -> usize {
        if !(v > 0.0) {
            return 0;
        }
        let e = v.log2().floor() as i32 + BUCKET_BIAS;
        e.clamp(0, HIST_BUCKETS as i32 - 1) as usize
    }

    /// Geometric midpoint of bucket `i` — the value quantiles report.
    fn bucket_mid(i: usize) -> f64 {
        2f64.powi(i as i32 - BUCKET_BIAS) * std::f64::consts::SQRT_2
    }

    pub fn record(&self, v: f64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micro.fetch_add((v.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Approximate quantile `q ∈ [0, 1]` from the bucket counts.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(HIST_BUCKETS - 1)
    }

    pub fn summary(&self) -> HistogramSummary {
        let count = self.count();
        let sum = self.sum_micro.load(Ordering::Relaxed) as f64 * 1e-6;
        HistogramSummary {
            count,
            mean: if count > 0 { sum / count as f64 } else { 0.0 },
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
        }
    }
}

/// The snapshot a histogram renders into exports.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
}

/// The process-wide metric table: names to leaked `&'static` handles.
pub struct Registry {
    counters: Mutex<BTreeMap<String, &'static Counter>>,
    gauges: Mutex<BTreeMap<String, &'static Gauge>>,
    histograms: Mutex<BTreeMap<String, &'static Histogram>>,
}

impl Registry {
    fn new() -> Registry {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
        }
    }

    fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Counter by name (created on first use; same name → same handle).
    pub fn counter(&self, name: &str) -> &'static Counter {
        let mut map = Self::lock(&self.counters);
        if let Some(c) = map.get(name) {
            return c;
        }
        let c: &'static Counter = Box::leak(Box::new(Counter::new()));
        map.insert(name.to_string(), c);
        c
    }

    pub fn gauge(&self, name: &str) -> &'static Gauge {
        let mut map = Self::lock(&self.gauges);
        if let Some(g) = map.get(name) {
            return g;
        }
        let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
        map.insert(name.to_string(), g);
        g
    }

    pub fn histogram(&self, name: &str) -> &'static Histogram {
        let mut map = Self::lock(&self.histograms);
        if let Some(h) = map.get(name) {
            return h;
        }
        let h: &'static Histogram = Box::leak(Box::new(Histogram::new()));
        map.insert(name.to_string(), h);
        h
    }

    /// A name-prefixed view — the per-`Session`/per-job form, so scoped
    /// metrics (`job.<id>.…`) coexist in one process snapshot.
    pub fn scope(&self, prefix: &str) -> Scope {
        Scope { prefix: prefix.to_string() }
    }

    /// One-shot snapshot of every registered metric:
    /// `{counters:{..}, gauges:{..}, histograms:{name:{count,mean,p50,p90}}}`.
    pub fn snapshot_json(&self) -> Json {
        let counters: Vec<(String, Json)> = Self::lock(&self.counters)
            .iter()
            .map(|(k, c)| (k.clone(), num(c.get() as f64)))
            .collect();
        let gauges: Vec<(String, Json)> = Self::lock(&self.gauges)
            .iter()
            .map(|(k, g)| (k.clone(), num(g.get() as f64)))
            .collect();
        let hists: Vec<(String, Json)> = Self::lock(&self.histograms)
            .iter()
            .map(|(k, h)| {
                let s = h.summary();
                (
                    k.clone(),
                    obj(vec![
                        ("count", num(s.count as f64)),
                        ("mean", num(s.mean)),
                        ("p50", num(s.p50)),
                        ("p90", num(s.p90)),
                    ]),
                )
            })
            .collect();
        let owned = |v: Vec<(String, Json)>| {
            Json::Obj(v.into_iter().collect::<BTreeMap<String, Json>>())
        };
        obj(vec![
            ("counters", owned(counters)),
            ("gauges", owned(gauges)),
            ("histograms", owned(hists)),
        ])
    }

    /// Zero every registered metric (bench/test isolation between
    /// telemetry modes; handles stay valid — cached call sites keep
    /// working).
    pub fn reset(&self) {
        for c in Self::lock(&self.counters).values() {
            c.v.store(0, Ordering::Relaxed);
        }
        for g in Self::lock(&self.gauges).values() {
            g.v.store(0, Ordering::Relaxed);
        }
        for h in Self::lock(&self.histograms).values() {
            for b in &h.buckets {
                b.store(0, Ordering::Relaxed);
            }
            h.count.store(0, Ordering::Relaxed);
            h.sum_micro.store(0, Ordering::Relaxed);
        }
    }
}

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

/// A prefixed view onto the process registry ([`Registry::scope`]).
pub struct Scope {
    prefix: String,
}

impl Scope {
    pub fn counter(&self, name: &str) -> &'static Counter {
        registry().counter(&format!("{}.{name}", self.prefix))
    }

    pub fn gauge(&self, name: &str) -> &'static Gauge {
        registry().gauge(&format!("{}.{name}", self.prefix))
    }

    pub fn histogram(&self, name: &str) -> &'static Histogram {
        registry().histogram(&format!("{}.{name}", self.prefix))
    }

    pub fn prefix(&self) -> &str {
        &self.prefix
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global and `reset` zeroes everything, so
    /// tests that assert absolute values serialize against it.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static L: Mutex<()> = Mutex::new(());
        L.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn counter_and_gauge_accumulate() {
        let _g = test_lock();
        let c = registry().counter("test.metrics.counter");
        let before = c.get();
        c.add(3);
        c.add(2);
        assert_eq!(c.get(), before + 5);
        // Same name resolves to the same handle.
        assert_eq!(registry().counter("test.metrics.counter").get(), before + 5);

        let g = registry().gauge("test.metrics.gauge");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
    }

    #[test]
    fn histogram_quantiles_are_log_bucket_accurate() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(0.001); // 1 ms
        }
        for _ in 0..10 {
            h.record(1.0); // 1 s tail
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean - (90.0 * 0.001 + 10.0) / 100.0).abs() < 1e-6, "mean={}", s.mean);
        // p50 lands in the 1ms bucket, p90 still below the 1s tail, and
        // quantiles are within the bucket's √2 resolution.
        assert!(s.p50 > 0.0005 && s.p50 < 0.002, "p50={}", s.p50);
        assert!(s.p90 < 0.01, "p90={}", s.p90);
        assert!(h.quantile(0.99) > 0.5, "p99={}", h.quantile(0.99));
    }

    #[test]
    fn histogram_handles_degenerate_values() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram quantile is 0");
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        assert_eq!(h.count(), 3);
        assert!(h.summary().mean.is_finite());
    }

    #[test]
    fn scope_prefixes_names() {
        let _g = test_lock();
        let sc = registry().scope("test.scope.a");
        sc.counter("hits").add(1);
        assert_eq!(sc.prefix(), "test.scope.a");
        assert_eq!(registry().counter("test.scope.a.hits").get(), 1);
    }

    #[test]
    fn snapshot_is_key_sorted_and_byte_stable() {
        let _g = test_lock();
        // Register in an order that disagrees with the sorted one; the
        // BTreeMap-backed registry must still export sorted, identical
        // bytes on every snapshot (telemetry-invariance pin, DESIGN §11).
        registry().counter("test.det.zz").add(1);
        registry().counter("test.det.aa").add(2);
        registry().counter("test.det.mm").add(3);
        let a = registry().snapshot_json().to_string_compact();
        let b = registry().snapshot_json().to_string_compact();
        assert_eq!(a, b, "same state → byte-identical snapshots");
        let zz = a.find("test.det.zz").expect("zz present");
        let aa = a.find("test.det.aa").expect("aa present");
        let mm = a.find("test.det.mm").expect("mm present");
        assert!(aa < mm && mm < zz, "counter keys serialize sorted: {a}");
    }

    #[test]
    fn snapshot_includes_all_kinds_and_reset_zeroes() {
        let _g = test_lock();
        registry().counter("test.snap.c").add(4);
        registry().gauge("test.snap.g").set(-2);
        registry().histogram("test.snap.h").record(0.5);
        let snap = registry().snapshot_json();
        assert_eq!(
            snap.get("counters").and_then(|c| c.get("test.snap.c")).and_then(Json::as_f64),
            Some(4.0)
        );
        assert_eq!(
            snap.get("gauges").and_then(|g| g.get("test.snap.g")).and_then(Json::as_f64),
            Some(-2.0)
        );
        let h = snap.get("histograms").and_then(|h| h.get("test.snap.h")).unwrap();
        assert_eq!(h.get("count").and_then(Json::as_f64), Some(1.0));
        registry().reset();
        assert_eq!(registry().counter("test.snap.c").get(), 0);
        assert_eq!(registry().histogram("test.snap.h").count(), 0);
    }
}
