//! Low-resource LM SFT (the paper's Fig. 4 scenario): gradient
//! accumulation with B=32, b=8, b_micro=8 — baseline pays 4 BP passes per
//! update, ESWP pays 1 plus a cheap scoring FP. Built on the prelude's
//! session API; the transformer runtime needs AOT artifacts.
//!
//!     make artifacts && cargo run --release --example lm_sft_low_resource

use evosample::prelude::*;

fn main() -> anyhow::Result<()> {
    let dataset = DatasetConfig::LmCorpus { n: 1024, vocab: 1024, seq: 64 };
    let mut session = SessionBuilder::new("txf_lm", dataset)
        .named("lm_sft")
        .epochs(3)
        .batch_sizes(32, 8)
        .micro_batch(8) // A100-40GB style micro-batching
        .lr(LrSchedule::WarmupCosine { base_lr: 1e-4, warmup_frac: 0.1, min_lr: 0.0 })
        .test_n(128)
        .eval_every(1)
        .seed(3)
        .build()?;

    session.set_sampler(SamplerConfig::Uniform);
    let base = session.run()?;
    session.set_sampler(SamplerConfig::Eswp {
        beta1: 0.2,
        beta2: 0.8,
        anneal_frac: 0.05,
        prune_ratio: 0.2,
    });
    let eswp = session.run()?;

    println!("\n{:<10} {:>10} {:>10} {:>10} {:>10}", "method", "LM loss", "BP passes", "wall s", "eval loss");
    for r in [&base, &eswp] {
        println!(
            "{:<10} {:>10.4} {:>10} {:>10.2} {:>10.4}",
            r.sampler,
            r.loss_curve.last().unwrap(),
            r.cost.bp_passes,
            r.cost.train_wall_s(),
            r.final_eval.loss
        );
    }
    println!(
        "\nESWP: {:.1}% wall-clock saved; BP passes {} -> {} (the paper's Fig. 4 mechanism).",
        saved_time_pct(&base.cost, &eswp.cost),
        base.cost.bp_passes,
        eswp.cost.bp_passes
    );
    Ok(())
}
