//! Minimal in-tree reimplementation of the `anyhow` API surface this
//! workspace uses: `Error`, `Result`, the `anyhow!` / `bail!` / `ensure!`
//! macros, and the `Context` extension trait.
//!
//! The real crate is unavailable offline; this stand-in keeps the same
//! call sites source-compatible. Errors are a flat message chain (context
//! entries prepended, `: `-separated) — no backtraces, no downcasting.

/// Dynamic error type: a message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: std::fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (what `Context::context` does).
    pub fn context<C: std::fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion does not overlap `impl From<T> for T`. This is
// the same trick the real anyhow uses to make `?` work on foreign errors.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result`'s error. Implemented for any displayable
/// error type (covers both std errors and `anyhow::Error` itself).
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an `Error` from a format string (or any displayable expression).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Assert-or-early-return with an error built like `anyhow!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("bad thing {}", 42);
        assert_eq!(e.to_string(), "bad thing 42");
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let e2: Result<()> = Err(e).context("outermost");
        assert!(e2.unwrap_err().to_string().starts_with("outermost: outer"));
    }
}
