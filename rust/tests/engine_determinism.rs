//! Engine regression tests: the refactored execution engine must
//! reproduce the pre-engine trainer bit-for-bit on the sequential paths,
//! and the threaded mode must be deterministic and uphold the §D.5 sync
//! model.
//!
//! `reference_train` below is the pre-refactor `train_with_sampler` loop,
//! kept verbatim (modulo paths) as an executable specification. If the
//! engine ever drifts from it on `workers == 1` or the sequential
//! simulation, these tests fail with the exact curves in hand.

// These tests intentionally pin the deprecated `coordinator::train` shim.
#![allow(deprecated)]

use evosample::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use evosample::coordinator::{evaluate, train, CostSummary, TrainResult};
use evosample::data::loader::EpochLoader;
use evosample::data::{self, SplitDataset};
use evosample::runtime::native::NativeRuntime;
use evosample::runtime::{BatchBuf, BatchX, ModelRuntime};
use evosample::sampler::evolved::Evolved;
use evosample::sampler::{self, Sampler};
use evosample::util::timer::{phase, PhaseTimers};
use evosample::util::Pcg64;

/// The pre-refactor trainer loop, verbatim (an executable specification).
fn reference_train(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
    mut sampler: Box<dyn Sampler>,
) -> anyhow::Result<TrainResult> {
    let mut rng = Pcg64::new(cfg.seed);
    rt.init(cfg.seed as i32)?;

    let mut timers = PhaseTimers::new();
    let mut meta_buf = BatchBuf::new();
    let mut mini_buf = BatchBuf::new();
    let train_ds = &data.train;
    let n = train_ds.n;
    let classes = train_ds.classes.max(1);
    let mut class_bp_counts = vec![0u64; classes];

    let total_steps = cfg.epochs * n.div_ceil(cfg.meta_batch);
    let mut step_idx = 0usize;

    let mut fp_samples = 0u64;
    let mut bp_samples = 0u64;
    let mut bp_passes = 0u64;
    let mut steps = 0u64;
    let mut loss_curve = Vec::with_capacity(cfg.epochs);
    let mut eval_curve = Vec::new();
    let mut bp_at_eval = Vec::new();

    let workers = cfg.workers.max(1);

    for epoch in 0..cfg.epochs {
        let kept = timers.time(phase::PRUNE, || sampler.on_epoch_start(epoch, &mut rng));
        anyhow::ensure!(!kept.is_empty(), "sampler kept nothing at epoch {epoch}");

        let mut loaders: Vec<EpochLoader> = if workers == 1 {
            vec![EpochLoader::new(&kept, cfg.meta_batch, &mut rng)]
        } else {
            (0..workers)
                .map(|w| {
                    let shard: Vec<u32> =
                        kept.iter().copied().skip(w).step_by(workers).collect();
                    let shard = if shard.is_empty() { kept.clone() } else { shard };
                    let mut wrng = rng.fork(0xd15c0 + w as u64);
                    EpochLoader::new(&shard, cfg.meta_batch, &mut wrng)
                })
                .collect()
        };
        let mut sync_buf: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();

        let mut epoch_loss_sum = 0.0f64;
        let mut epoch_loss_cnt = 0u64;

        'rounds: loop {
            let mut progressed = false;
            for loader in loaders.iter_mut() {
                let Some(meta) = loader.next_batch() else { continue };
                progressed = true;

                timers.time(phase::DATA, || meta_buf.fill(train_ds, &meta));

                let selecting = cfg.mini_batch < cfg.meta_batch;
                if selecting && sampler.needs_meta_losses(epoch) {
                    let losses = timers.time(phase::SCORING_FP, || {
                        rt.loss_fwd(meta_buf.x(train_ds), &meta_buf.y, meta.len())
                    })?;
                    fp_samples += meta.len() as u64;
                    if workers == 1 {
                        timers.time(phase::SELECT, || {
                            sampler.observe_meta(&meta, &losses, epoch)
                        });
                    } else {
                        sampler.observe_meta(&meta, &losses, epoch);
                        sync_buf.push((meta.clone(), losses));
                    }
                }

                let sel = timers.time(phase::SELECT, || {
                    sampler.select(&meta, cfg.mini_batch, epoch, &mut rng)
                });

                let bsz = sel.indices.len();
                let (buf, y_ref): (&BatchBuf, &Vec<i32>) = if sel.indices == meta {
                    (&meta_buf, &meta_buf.y)
                } else {
                    timers.time(phase::DATA, || mini_buf.fill(train_ds, &sel.indices));
                    (&mini_buf, &mini_buf.y)
                };

                let lr = cfg.lr.lr_at(step_idx, total_steps) as f32;

                let micro = if cfg.micro_batch > 0 && cfg.micro_batch < bsz {
                    cfg.micro_batch
                } else {
                    bsz
                };
                let mut all_losses = Vec::with_capacity(bsz);
                let mut mean_acc = 0.0f64;
                let mut off = 0usize;
                let x_len = train_ds.x_len();
                let y_len = train_ds.y_dim;
                while off < bsz {
                    let m = micro.min(bsz - off);
                    let out = timers.time(phase::TRAIN_BP, || {
                        let x = match buf.x(train_ds) {
                            BatchX::F32(v) => BatchX::F32(&v[off * x_len..(off + m) * x_len]),
                            BatchX::I32(v) => BatchX::I32(&v[off * x_len..(off + m) * x_len]),
                        };
                        rt.train_step(
                            x,
                            &y_ref[off * y_len..(off + m) * y_len],
                            &sel.weights[off..off + m],
                            lr,
                            m,
                        )
                    })?;
                    bp_passes += 1;
                    bp_samples += m as u64;
                    mean_acc += out.mean_loss as f64 * m as f64;
                    all_losses.extend_from_slice(&out.losses);
                    off += m;
                }
                let step_mean = mean_acc / bsz as f64;
                epoch_loss_sum += step_mean;
                epoch_loss_cnt += 1;

                if train_ds.y_dim == 1 && train_ds.classes > 0 {
                    for &i in &sel.indices {
                        class_bp_counts[train_ds.clean_class[i as usize] as usize] += 1;
                    }
                }

                if workers == 1 {
                    timers.time(phase::SELECT, || {
                        sampler.observe_train(&sel.indices, &all_losses, epoch)
                    });
                } else {
                    sync_buf.push((sel.indices.clone(), all_losses));
                }

                step_idx += 1;
                steps += 1;
            }
            if !progressed {
                break 'rounds;
            }
        }

        if workers > 1 && !sync_buf.is_empty() {
            timers.time(phase::SELECT, || {
                for (idx, losses) in sync_buf.drain(..) {
                    sampler.observe_train(&idx, &losses, epoch);
                }
            });
        }

        loss_curve.push(if epoch_loss_cnt > 0 {
            epoch_loss_sum / epoch_loss_cnt as f64
        } else {
            f64::NAN
        });

        let at_eval_point = cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0;
        if at_eval_point || epoch + 1 == cfg.epochs {
            let stats = timers.time(phase::EVAL, || evaluate(rt, data))?;
            eval_curve.push((epoch, stats.loss, stats.accuracy));
            bp_at_eval.push(bp_samples);
        }
    }

    let final_eval = eval_curve
        .last()
        .map(|&(_, l, a)| evosample::coordinator::EvalStats { loss: l, accuracy: a })
        .unwrap_or_default();
    let cost = CostSummary::from_run(
        &timers,
        fp_samples,
        bp_samples,
        bp_passes,
        rt.flops_per_sample_fwd(),
    );

    Ok(TrainResult {
        name: cfg.name.clone(),
        sampler: sampler.name().to_string(),
        seed: cfg.seed,
        epochs: cfg.epochs,
        steps,
        loss_curve,
        eval_curve,
        final_eval,
        timers,
        cost,
        class_bp_counts,
        bp_at_eval,
    })
}

fn setup(sampler_cfg: SamplerConfig, n: usize, seed: u64) -> (RunConfig, SplitDataset) {
    let ds = DatasetConfig::SynthCifar { n, classes: 4, label_noise: 0.05, hard_frac: 0.2 };
    let split = data::build(&ds, 128, 42);
    let mut cfg = RunConfig::new("engine_det", "native", ds);
    cfg.epochs = 5;
    cfg.meta_batch = 64;
    cfg.mini_batch = 16;
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
    cfg.test_n = 128;
    cfg.seed = seed;
    cfg.sampler = sampler_cfg;
    (cfg, split)
}

fn assert_identical(a: &TrainResult, b: &TrainResult) {
    assert_eq!(a.loss_curve, b.loss_curve, "loss curves diverged");
    assert_eq!(a.eval_curve, b.eval_curve, "eval curves diverged");
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.cost.fp_samples, b.cost.fp_samples);
    assert_eq!(a.cost.bp_samples, b.cost.bp_samples);
    assert_eq!(a.cost.bp_passes, b.cost.bp_passes);
    assert_eq!(a.class_bp_counts, b.class_bp_counts);
    assert_eq!(a.bp_at_eval, b.bp_at_eval);
}

#[test]
fn engine_single_worker_matches_pre_refactor_loop_exactly() {
    for sampler_cfg in [
        SamplerConfig::Uniform,
        SamplerConfig::es_default(),
        SamplerConfig::eswp_default(),
        SamplerConfig::infobatch_default(),
    ] {
        let (cfg, split) = setup(sampler_cfg.clone(), 512, 7);
        let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
        let engine_run = train(&cfg, &mut rt, &split).unwrap();
        let reference_sampler = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let reference = reference_train(&cfg, &mut rt, &split, reference_sampler).unwrap();
        assert_identical(&engine_run, &reference);
    }
}

#[test]
fn engine_simulation_matches_pre_refactor_loop_exactly() {
    let (mut cfg, split) = setup(SamplerConfig::eswp_default(), 512, 11);
    cfg.workers = 4;
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let engine_run = train(&cfg, &mut rt, &split).unwrap();
    let reference_sampler = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
    let reference = reference_train(&cfg, &mut rt, &split, reference_sampler).unwrap();
    assert_identical(&engine_run, &reference);
}

#[test]
fn grad_accum_path_matches_pre_refactor_loop_exactly() {
    let (mut cfg, split) = setup(SamplerConfig::es_default(), 256, 3);
    cfg.meta_batch = 32;
    cfg.mini_batch = 16;
    cfg.micro_batch = 4;
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let engine_run = train(&cfg, &mut rt, &split).unwrap();
    let reference_sampler = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
    let reference = reference_train(&cfg, &mut rt, &split, reference_sampler).unwrap();
    assert_identical(&engine_run, &reference);
}

// ---- §D.5 sync-model property: sharded == single-table -----------------

#[test]
fn sharded_simulation_tables_equal_single_worker_batched_observation() {
    // The sequential simulation interleaves per-worker observations
    // round-robin into the shared table and defers train losses to the
    // epoch end. An equivalent single-worker run sees each worker's whole
    // epoch stream *grouped* (worker 0's batches, then worker 1's, ...)
    // with the same batched end-of-epoch train observation. Because
    // shards are disjoint, the two orders must leave the ES tables
    // bit-identical — the commutativity the §D.5 sync model rests on.
    evosample::util::proptest::check("sim tables == single batched", 40, |g| {
        let n = g.usize_in(16, 160);
        let workers = g.usize_in(2, 5);
        let epochs = 5;
        // anneal_frac 0.2 => epochs 0 and 4 annealed, so both the
        // immediate (meta) and the deferred (train) update paths apply.
        let mut sim = Evolved::new(n, epochs, 0.2, 0.9, 0.2, 0.0);
        let mut single = Evolved::new(n, epochs, 0.2, 0.9, 0.2, 0.0);

        for epoch in 0..epochs {
            // Disjoint round-robin shards of the full index set.
            let shards: Vec<Vec<u32>> = (0..workers)
                .map(|w| (0..n as u32).skip(w).step_by(workers).collect())
                .collect();
            // per_worker[w] = (meta batches, deferred train batches).
            let mut per_worker: Vec<(Vec<(Vec<u32>, Vec<f32>)>, Vec<(Vec<u32>, Vec<f32>)>)> =
                vec![(Vec::new(), Vec::new()); workers];
            for round in 0..3 {
                for (w, shard) in shards.iter().enumerate() {
                    if shard.is_empty() {
                        continue;
                    }
                    let take = shard.len().min(8);
                    let start = (round * take) % shard.len();
                    let idx: Vec<u32> =
                        (0..take).map(|k| shard[(start + k) % shard.len()]).collect();
                    let meta_losses: Vec<f32> = idx.iter().map(|_| g.f32_in(0.0, 4.0)).collect();
                    let train_losses: Vec<f32> =
                        idx.iter().map(|_| g.f32_in(0.0, 4.0)).collect();
                    // Sim: apply meta immediately, in interleaved order.
                    sim.observe_meta(&idx, &meta_losses, epoch);
                    per_worker[w].0.push((idx.clone(), meta_losses));
                    per_worker[w].1.push((idx, train_losses));
                }
            }
            // Sim: epoch-end sync replays deferred train losses,
            // interleaved as they were pushed.
            for round in 0..3 {
                for (_, deferred) in &per_worker {
                    if let Some((idx, losses)) = deferred.get(round) {
                        sim.observe_train(idx, losses, epoch);
                    }
                }
            }
            // Single worker: each worker's stream grouped, then all train
            // losses batched at the epoch end.
            for (metas, _) in &per_worker {
                for (idx, losses) in metas {
                    single.observe_meta(idx, losses, epoch);
                }
            }
            for (_, deferred) in &per_worker {
                for (idx, losses) in deferred {
                    single.observe_train(idx, losses, epoch);
                }
            }
        }
        evosample::prop_assert!(
            sim.weights_table() == single.weights_table(),
            "weight tables diverged (n={n}, W={workers})"
        );
        evosample::prop_assert!(
            sim.scores_table() == single.scores_table(),
            "score tables diverged (n={n}, W={workers})"
        );
        Ok(())
    });
}

#[test]
fn threaded_sync_round_reconverges_replica_tables() {
    // End-to-end check of the engine's all-gather contract: three replicas
    // observe disjoint shards, the canonical merges every log and each
    // replica merges its peers' logs; afterwards all four tables agree.
    let n = 30usize;
    let epochs = 4;
    let make = || Evolved::new(n, epochs, 0.2, 0.8, 0.0, 0.3);
    let mut canonical = make();
    let mut replicas: Vec<Evolved> = (0..3).map(|_| make()).collect();
    let shards: Vec<Vec<u32>> =
        (0..3).map(|w| (0..n as u32).skip(w).step_by(3).collect()).collect();
    let mut rng = Pcg64::new(5);
    for (replica, shard) in replicas.iter_mut().zip(&shards) {
        replica.begin_shard(shard);
        for chunk in shard.chunks(4) {
            let losses: Vec<f32> = chunk.iter().map(|_| rng.f32() * 3.0).collect();
            replica.observe_meta(chunk, &losses, 1);
        }
    }
    let logs: Vec<_> = replicas.iter_mut().map(|r| r.export_observations()).collect();
    for (w, log) in logs.iter().enumerate() {
        canonical.merge_observations(log, 1);
        for (v, replica) in replicas.iter_mut().enumerate() {
            if v != w {
                replica.merge_observations(log, 1);
            }
        }
    }
    for replica in &replicas {
        assert_eq!(replica.weights_table(), canonical.weights_table());
        assert_eq!(replica.scores_table(), canonical.scores_table());
    }
    // And the canonical can prune on the merged view.
    let kept = canonical.on_epoch_start(1, &mut rng);
    assert_eq!(kept.len(), 21, "30 * (1 - 0.3) = 21 kept");
}

// ---- threaded mode ------------------------------------------------------

#[test]
fn threaded_engine_runs_deterministically_and_learns() {
    let (mut cfg, split) = setup(SamplerConfig::eswp_default(), 512, 13);
    cfg.workers = 4;
    cfg.threaded_workers = true;
    cfg.epochs = 6;
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let a = train(&cfg, &mut rt, &split).unwrap();
    let b = train(&cfg, &mut rt, &split).unwrap();
    assert_eq!(a.loss_curve, b.loss_curve, "threaded runs must be seed-deterministic");
    assert_eq!(a.cost.bp_samples, b.cost.bp_samples);
    assert!(a.steps > 0);
    assert!(
        a.final_eval.accuracy > 0.3,
        "threaded acc {} should beat 4-class chance",
        a.final_eval.accuracy
    );
    assert!(a.loss_curve.first().unwrap() > a.loss_curve.last().unwrap());
    assert!(a.cost.sync_s >= 0.0);
}

#[test]
fn threaded_engine_with_midepoch_param_sync() {
    let (mut cfg, split) = setup(SamplerConfig::Uniform, 512, 17);
    cfg.workers = 2;
    cfg.threaded_workers = true;
    cfg.sync_every = 1;
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let r = train(&cfg, &mut rt, &split).unwrap();
    assert!(r.final_eval.accuracy > 0.35, "acc {}", r.final_eval.accuracy);
    // 512 samples, 4 shards of 128... workers=2 => shards of 256 => 4
    // meta-batches each; sync_every=1 => 4 mid-epoch syncs + 1 boundary.
    assert!(r.cost.sync_s > 0.0, "mid-epoch syncs must be accounted");
}

#[test]
fn threaded_engine_covers_all_kept_samples() {
    let (mut cfg, split) = setup(SamplerConfig::Uniform, 256, 19);
    cfg.workers = 4;
    cfg.threaded_workers = true;
    cfg.mini_batch = cfg.meta_batch; // no batch selection: full coverage
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let r = train(&cfg, &mut rt, &split).unwrap();
    // Every kept sample flows through BP once per epoch (modulo ragged
    // padding, which only adds).
    assert!(r.cost.bp_samples >= (cfg.epochs * 256) as u64);
}

#[test]
fn threaded_engine_handles_fewer_kept_samples_than_workers() {
    // kept.len() < workers must clamp to disjoint non-empty shards rather
    // than duplicating the kept set across replicas.
    let ds = DatasetConfig::SynthCifar { n: 3, classes: 2, label_noise: 0.0, hard_frac: 0.0 };
    let split = data::build(&ds, 16, 5);
    let mut cfg = RunConfig::new("tiny_threaded", "native", ds);
    cfg.epochs = 2;
    cfg.meta_batch = 1;
    cfg.mini_batch = 1;
    cfg.lr = LrSchedule::Const { lr: 0.01 };
    cfg.test_n = 16;
    cfg.workers = 4;
    cfg.threaded_workers = true;
    cfg.sampler = SamplerConfig::Uniform;
    let mut rt = NativeRuntime::new(split.train.x_len(), 8, 2);
    let a = train(&cfg, &mut rt, &split).unwrap();
    let b = train(&cfg, &mut rt, &split).unwrap();
    // 3 kept / 4 workers => 3 effective workers, 1 sample each, 2 epochs.
    assert_eq!(a.cost.bp_samples, 6);
    assert_eq!(a.loss_curve, b.loss_curve);
}

#[test]
fn replayed_epoch_start_reproduces_infobatch_rescale_on_replicas() {
    // The threaded engine replays on_epoch_start on every replica with a
    // clone of the canonical's pruning RNG; with synced score tables this
    // must reproduce both the kept set and the 1/(1-r) rescale weights
    // that InfoBatch's select() applies.
    use evosample::sampler::infobatch::InfoBatch;
    let n = 200usize;
    let mut canonical = InfoBatch::new(n, 10, 0.5, 0.0);
    let mut replica = InfoBatch::new(n, 10, 0.5, 0.0);
    let idx: Vec<u32> = (0..n as u32).collect();
    let losses: Vec<f32> = (0..n).map(|i| if i < 100 { 0.1 } else { 10.0 }).collect();
    // Canonical observes directly; the replica receives the same state
    // through the sync-round merge path.
    canonical.observe_train(&idx, &losses, 0);
    replica.merge_observations(&[(idx.clone(), losses)], 0);

    let prune_rng = Pcg64::new(77);
    let kept_canonical = canonical.on_epoch_start(1, &mut prune_rng.clone());
    let kept_replica = replica.on_epoch_start(1, &mut prune_rng.clone());
    assert_eq!(kept_canonical, kept_replica, "replayed RNG must reproduce the prune");
    assert!(kept_canonical.len() < n, "something must have been pruned");

    let mut rng = Pcg64::new(1);
    let sel_c = canonical.select(&kept_canonical, kept_canonical.len(), 1, &mut rng.clone());
    let sel_r = replica.select(&kept_replica, kept_replica.len(), 1, &mut rng.clone());
    assert_eq!(sel_c.weights, sel_r.weights, "rescale tables must match");
    assert!(
        sel_r.weights.iter().any(|&w| (w - 2.0).abs() < 1e-6),
        "below-mean survivors must carry the 1/(1-r) rescale on the replica"
    );
}

// ---- frequency tuning (run.score_every, DESIGN.md §8) -------------------

/// With score_every = 1 (the default) every engine mode must reproduce
/// the pre-change behavior bit-for-bit: the sequential modes against the
/// verbatim pre-refactor reference loop (which has no cadence logic at
/// all), the threaded mode against a run of the untouched default config
/// (same RNG schedule, same arithmetic).
#[test]
fn score_every_1_is_bit_for_bit_pre_change_in_all_modes() {
    // Single worker vs the pre-refactor reference.
    for sampler_cfg in [SamplerConfig::es_default(), SamplerConfig::eswp_default()] {
        let (mut cfg, split) = setup(sampler_cfg.clone(), 512, 7);
        cfg.score_every = 1;
        let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
        let engine_run = train(&cfg, &mut rt, &split).unwrap();
        let reference_sampler = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let reference = reference_train(&cfg, &mut rt, &split, reference_sampler).unwrap();
        assert_identical(&engine_run, &reference);
    }
    // Sequential simulation vs the reference.
    let (mut cfg, split) = setup(SamplerConfig::es_default(), 512, 11);
    cfg.workers = 4;
    cfg.score_every = 1;
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let engine_run = train(&cfg, &mut rt, &split).unwrap();
    let reference_sampler = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
    let reference = reference_train(&cfg, &mut rt, &split, reference_sampler).unwrap();
    assert_identical(&engine_run, &reference);
    // Threaded: explicit k=1 vs the default config (guards both the
    // default value and any k==1 gating asymmetry on the replica path).
    let (mut cfg_default, split) = setup(SamplerConfig::eswp_default(), 512, 13);
    cfg_default.workers = 4;
    cfg_default.threaded_workers = true;
    let mut cfg_k1 = cfg_default.clone();
    cfg_k1.score_every = 1;
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let a = train(&cfg_default, &mut rt, &split).unwrap();
    let b = train(&cfg_k1, &mut rt, &split).unwrap();
    assert_identical(&a, &b);
}

/// Set-level and baseline methods never run the scoring FP, so the
/// cadence knob must be a strict no-op for them — any k, any mode.
#[test]
fn score_every_is_noop_for_non_scoring_methods() {
    for sampler_cfg in [SamplerConfig::Uniform, SamplerConfig::infobatch_default()] {
        for threaded in [false, true] {
            let (mut cfg, split) = setup(sampler_cfg.clone(), 512, 29);
            if threaded {
                cfg.workers = 4;
                cfg.threaded_workers = true;
            }
            let mut cfg_k4 = cfg.clone();
            cfg_k4.score_every = 4;
            let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
            let a = train(&cfg, &mut rt, &split).unwrap();
            let b = train(&cfg_k4, &mut rt, &split).unwrap();
            assert_identical(&a, &b);
            assert_eq!(a.cost.fp_samples, 0);
            assert_eq!(b.cost.fp_passes, 0);
        }
    }
}

/// Strided runs are seed-deterministic in every mode, and the stale
/// steps actually skip the scoring FP (fp accounting shrinks ~k-fold).
#[test]
fn score_every_4_is_deterministic_and_amortizes_fp() {
    for threaded in [false, true] {
        // n=1024 so threaded shards carry 4 meta-batches per epoch — the
        // per-epoch worker cadence then amortizes the full 4x (a shard
        // with fewer than k eligible steps caps the saving at its length).
        let (mut cfg, split) = setup(SamplerConfig::es_default(), 1024, 31);
        cfg.score_every = 4;
        if threaded {
            cfg.workers = 4;
            cfg.threaded_workers = true;
        }
        let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
        let a = train(&cfg, &mut rt, &split).unwrap();
        let b = train(&cfg, &mut rt, &split).unwrap();
        assert_eq!(a.loss_curve, b.loss_curve, "threaded={threaded}");
        assert_eq!(a.cost.fp_samples, b.cost.fp_samples, "threaded={threaded}");
        assert_eq!(a.cost.fp_passes, b.cost.fp_passes, "threaded={threaded}");

        let mut cfg_k1 = cfg.clone();
        cfg_k1.score_every = 1;
        let k1 = train(&cfg_k1, &mut rt, &split).unwrap();
        assert!(
            a.cost.fp_samples * 3 < k1.cost.fp_samples,
            "threaded={threaded}: fp_samples {} at k=4 vs {} at k=1",
            a.cost.fp_samples,
            k1.cost.fp_samples
        );
        assert_eq!(a.cost.bp_samples, k1.cost.bp_samples, "BP volume is cadence-independent");
    }
}

/// The fp_samples accounting contract: with every step scoring-eligible
/// (ES, anneal_frac = 0) and a single worker, fp_samples must equal
/// ⌈steps / k⌉ · meta_batch exactly — the scoring FP fires on eligible
/// steps 0, k, 2k, ... of the run and nowhere else.
#[test]
fn fp_samples_scale_as_ceil_steps_over_k_times_meta_batch() {
    evosample::util::proptest::check("fp_samples == ceil(steps/k)*B", 8, |g| {
        let k = g.usize_in(1, 8);
        let epochs = g.usize_in(1, 3);
        let n = 32 * g.usize_in(1, 4);
        let meta_batch = [16usize, 32][g.usize_in(0, 1)];
        let ds = DatasetConfig::SynthCifar {
            n,
            classes: 4,
            label_noise: 0.0,
            hard_frac: 0.2,
        };
        let split = data::build(&ds, 32, 99);
        let mut cfg = RunConfig::new("freq_prop", "native", ds);
        cfg.epochs = epochs;
        cfg.meta_batch = meta_batch;
        cfg.mini_batch = meta_batch / 2;
        cfg.score_every = k;
        cfg.lr = LrSchedule::Const { lr: 0.02 };
        cfg.test_n = 32;
        cfg.sampler = SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.0 };
        let mut rt = NativeRuntime::new(split.train.x_len(), 8, 4);
        let r = train(&cfg, &mut rt, &split).unwrap();
        let steps = r.steps as usize;
        let expected_passes = steps.div_ceil(k);
        evosample::prop_assert!(
            r.cost.fp_passes as usize == expected_passes,
            "fp_passes {} != ceil({steps}/{k}) = {expected_passes}",
            r.cost.fp_passes
        );
        evosample::prop_assert!(
            r.cost.fp_samples as usize == expected_passes * meta_batch,
            "fp_samples {} != {expected_passes} * {meta_batch}",
            r.cost.fp_samples
        );
        Ok(())
    });
}

// ---- telemetry (run.telemetry, DESIGN.md §11) ---------------------------

/// Telemetry is observational only: raising the process level to trace
/// must leave every engine mode bit-for-bit on its untraced result —
/// same curves, same sample accounting, same class histograms. (The
/// raise is process-global and sticky, so tests running after this one
/// simply execute traced; the grammar suite separately pins that the
/// event sequence is level-invariant.)
#[test]
fn trace_telemetry_is_bit_for_bit_in_all_modes() {
    let run = |cfg: &RunConfig, split: &SplitDataset| {
        let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
        train(cfg, &mut rt, split).unwrap()
    };
    let (cfg_single, split_single) = setup(SamplerConfig::es_default(), 512, 7);
    let (mut cfg_threaded, split_threaded) = setup(SamplerConfig::eswp_default(), 512, 13);
    cfg_threaded.workers = 4;
    cfg_threaded.threaded_workers = true;
    let base_single = run(&cfg_single, &split_single);
    let base_threaded = run(&cfg_threaded, &split_threaded);
    evosample::obs::raise_level(evosample::obs::TRACE);
    let traced_single = run(&cfg_single, &split_single);
    let traced_threaded = run(&cfg_threaded, &split_threaded);
    assert_identical(&base_single, &traced_single);
    assert_identical(&base_threaded, &traced_threaded);
    assert!(evosample::obs::trace_on(), "level stays raised");
}

// ---- scoring precision (run.scoring_precision, DESIGN.md §9) ------------

/// With `scoring_precision = "exact"` (the default, pinned explicitly
/// here) the engine must stay bit-for-bit on the pre-change reference
/// loop: the bf16 ranked path is never entered, and the scoring FP goes
/// through the same exact kernels the reference calls via `loss_fwd`.
#[test]
fn exact_scoring_precision_is_bit_for_bit_on_the_reference_loop() {
    use evosample::config::ScoringPrecision;
    for sampler_cfg in [SamplerConfig::es_default(), SamplerConfig::eswp_default()] {
        let (mut cfg, split) = setup(sampler_cfg.clone(), 512, 7);
        cfg.scoring_precision = ScoringPrecision::Exact;
        let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
        let engine_run = train(&cfg, &mut rt, &split).unwrap();
        let reference_sampler = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let reference = reference_train(&cfg, &mut rt, &split, reference_sampler).unwrap();
        assert_identical(&engine_run, &reference);
    }
}

// ---- pruned-set batching floor (min-keep clamp) -------------------------

/// Documents the hazard the clamp guards against: a kept set smaller
/// than one meta-batch makes the loader's wraparound pad emit duplicate
/// indices INSIDE a single meta-batch.
#[test]
fn loader_duplicates_in_batch_when_kept_below_meta_batch() {
    let kept: Vec<u32> = (0..13).collect();
    let mut loader = EpochLoader::new(&kept, 64, &mut Pcg64::new(1));
    let batch = loader.next_batch().unwrap();
    assert_eq!(batch.len(), 64);
    let mut sorted = batch.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert!(sorted.len() < batch.len(), "wraparound must duplicate here");
}

/// Regression: a high-prune ESWP config whose kept set would drop below
/// one meta-batch is clamped back up, so no meta-batch ever carries a
/// duplicate index (the without-replacement contract of
/// `weights::sample_without_replacement` holds end-to-end).
#[test]
fn high_prune_configs_never_duplicate_indices_within_a_meta_batch() {
    use evosample::prelude::{Event, SessionBuilder};
    use std::sync::{Arc, Mutex};
    let ds = DatasetConfig::SynthCifar { n: 128, classes: 4, label_noise: 0.0, hard_frac: 0.2 };
    let split = data::build(&ds, 64, 3);
    let mut cfg = RunConfig::new("min_keep", "native", ds);
    cfg.epochs = 4;
    cfg.meta_batch = 64;
    cfg.mini_batch = 16;
    cfg.lr = LrSchedule::Const { lr: 0.02 };
    cfg.test_n = 64;
    // r=0.9 over n=128 keeps ceil(12.8)=13 < B=64 without the clamp.
    cfg.sampler = SamplerConfig::Eswp {
        beta1: 0.2,
        beta2: 0.8,
        anneal_frac: 0.0,
        prune_ratio: 0.9,
    };
    let kepts: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = kepts.clone();
    let mut rt = NativeRuntime::new(split.train.x_len(), 16, 4);
    let r = SessionBuilder::from_config(cfg.clone())
        .split(split)
        .runtime_mut(&mut rt)
        .on_event(move |ev: &Event| {
            if let Event::EpochStart { kept, .. } = ev {
                sink.lock().unwrap().push(*kept);
            }
        })
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(r.steps > 0);
    let kepts = kepts.lock().unwrap();
    assert_eq!(kepts.len(), cfg.epochs);
    for (epoch, &kept) in kepts.iter().enumerate() {
        assert!(
            kept >= cfg.meta_batch,
            "epoch {epoch}: kept {kept} < meta_batch {} — clamp failed",
            cfg.meta_batch
        );
    }
    // The clamp floors at B, it does not disable pruning: with r=0.9 the
    // kept set must still be far below the full dataset.
    assert!(kepts.iter().any(|&k| k < 128), "pruning still active");
}

/// The sequential simulation shards the kept set too; its effective
/// worker count is floored at kept/B for the same reason. (Identity —
/// same shards, same RNG forks — for every config whose shards were
/// already >= one meta-batch, so the bit-for-bit reference pin holds.)
#[test]
fn simulation_shards_stay_at_least_one_meta_batch() {
    let ds = DatasetConfig::SynthCifar { n: 192, classes: 4, label_noise: 0.0, hard_frac: 0.2 };
    let split = data::build(&ds, 64, 5);
    let mut cfg = RunConfig::new("sim_shard_floor", "native", ds);
    cfg.epochs = 2;
    cfg.meta_batch = 64;
    cfg.mini_batch = 64;
    cfg.lr = LrSchedule::Const { lr: 0.02 };
    cfg.test_n = 64;
    cfg.workers = 4; // 192/64 = 3 full shards => only 3 effective workers
    cfg.sampler = SamplerConfig::Uniform;
    let mut rt = NativeRuntime::new(split.train.x_len(), 16, 4);
    let r = train(&cfg, &mut rt, &split).unwrap();
    // 3 effective workers × 1 batch of 64 × 2 epochs — no wraparound pad,
    // so no duplicate indices inside any meta-batch (the old behavior
    // split 4 shards of 48, each padded up to 64 with duplicates).
    assert_eq!(r.cost.bp_samples, (2 * 192) as u64);
    assert_eq!(r.steps, 6);
}

/// Threaded mode shards the kept set; shards shorter than one meta-batch
/// would reintroduce the duplicate-index hazard per worker, so the
/// effective worker count is clamped to kept/B.
#[test]
fn threaded_shards_stay_at_least_one_meta_batch() {
    let ds = DatasetConfig::SynthCifar { n: 192, classes: 4, label_noise: 0.0, hard_frac: 0.2 };
    let split = data::build(&ds, 64, 5);
    let mut cfg = RunConfig::new("shard_floor", "native", ds);
    cfg.epochs = 2;
    cfg.meta_batch = 64;
    cfg.mini_batch = 64;
    cfg.lr = LrSchedule::Const { lr: 0.02 };
    cfg.test_n = 64;
    cfg.workers = 4; // 192/64 = 3 full shards => only 3 effective workers
    cfg.threaded_workers = true;
    cfg.sampler = SamplerConfig::Uniform;
    let mut rt = NativeRuntime::new(split.train.x_len(), 16, 4);
    let a = train(&cfg, &mut rt, &split).unwrap();
    let b = train(&cfg, &mut rt, &split).unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    // 3 effective workers × 1 batch of 64 × 2 epochs, no wraparound pad.
    assert_eq!(a.cost.bp_samples, (2 * 192) as u64);
}

#[test]
fn spawn_replica_default_is_graceful_unsupported() {
    struct NoReplicas;
    impl ModelRuntime for NoReplicas {
        fn param_count(&self) -> usize {
            0
        }
        fn init(&mut self, _seed: i32) -> anyhow::Result<()> {
            Ok(())
        }
        fn loss_fwd_into(
            &mut self,
            _x: BatchX<'_>,
            _y: &[i32],
            n: usize,
            out: &mut Vec<f32>,
        ) -> anyhow::Result<()> {
            out.resize(out.len() + n, 0.0);
            Ok(())
        }
        fn train_step(
            &mut self,
            _x: BatchX<'_>,
            _y: &[i32],
            _w: &[f32],
            _lr: f32,
            n: usize,
        ) -> anyhow::Result<evosample::runtime::StepOutput> {
            Ok(evosample::runtime::StepOutput { losses: vec![0.0; n], mean_loss: 0.0 })
        }
        fn eval(
            &mut self,
            _x: BatchX<'_>,
            _y: &[i32],
            n: usize,
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
            Ok((vec![0.0; n], vec![0.0; n]))
        }
        fn train_sizes(&self) -> Vec<usize> {
            Vec::new()
        }
        fn fwd_size(&self) -> usize {
            0
        }
        fn eval_size(&self) -> usize {
            0
        }
        fn get_params(&mut self) -> anyhow::Result<Vec<f32>> {
            Ok(Vec::new())
        }
        fn set_params(&mut self, _params: &[f32]) -> anyhow::Result<()> {
            Ok(())
        }
        fn flops_per_sample_fwd(&self) -> u64 {
            1
        }
    }
    let rt = NoReplicas;
    let err = rt.spawn_replica().unwrap_err().to_string();
    assert!(err.contains("threaded replicas"), "{err}");

    // And a threaded run on such a runtime fails cleanly, not silently.
    let (mut cfg, split) = setup(SamplerConfig::Uniform, 256, 23);
    cfg.workers = 2;
    cfg.threaded_workers = true;
    let mut rt = NoReplicas;
    let err = train(&cfg, &mut rt, &split).unwrap_err().to_string();
    assert!(err.contains("threaded replicas"), "{err}");
}
