//! Baseline samplers: standard batched sampling (no selection) and purely
//! random set-level pruning (the Tab. 7 "Random" ablation).

use super::{Sampler, Selection};
use crate::util::json::Json;
use crate::util::Pcg64;

/// Standard batched sampling — the paper's Baseline. No selection at all:
/// every meta-batch trains in full.
pub struct Uniform {
    n: usize,
}

impl Uniform {
    pub fn new(n: usize) -> Self {
        Uniform { n }
    }
}

impl Sampler for Uniform {
    fn name(&self) -> &'static str {
        "baseline"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn select(&mut self, meta: &[u32], _mini: usize, _epoch: usize, _rng: &mut Pcg64) -> Selection {
        Selection::unweighted(meta.to_vec())
    }

    // Stateless: checkpoint resume is exact with nothing to capture.
    fn state_json(&self) -> Option<Json> {
        Some(Json::Null)
    }

    fn restore_state(&mut self, _state: &Json) -> anyhow::Result<()> {
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Random set-level pruning: keep a uniform (1−r)·n subset each epoch,
/// ignoring all loss information. The Tab. 7 control showing that ESWP's
/// gains come from *informed* pruning.
pub struct RandomPrune {
    n: usize,
    prune_ratio: f64,
}

impl RandomPrune {
    pub fn new(n: usize, prune_ratio: f64) -> Self {
        assert!((0.0..1.0).contains(&prune_ratio));
        RandomPrune { n, prune_ratio }
    }
}

impl Sampler for RandomPrune {
    fn name(&self) -> &'static str {
        "random_prune"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn on_epoch_start(&mut self, _epoch: usize, rng: &mut Pcg64) -> Vec<u32> {
        let keep = ((1.0 - self.prune_ratio) * self.n as f64).ceil() as usize;
        let mut kept = rng.choose_k(self.n, keep.max(1));
        kept.sort_unstable();
        kept
    }

    // Stateless beyond the engine's RNG (captured separately by the
    // checkpoint), so resume is exact with nothing to serialize.
    fn state_json(&self) -> Option<Json> {
        Some(Json::Null)
    }

    fn restore_state(&mut self, _state: &Json) -> anyhow::Result<()> {
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_trains_whole_meta() {
        let mut u = Uniform::new(10);
        let meta = vec![1u32, 5, 9];
        let sel = u.select(&meta, 1, 0, &mut Pcg64::new(0));
        assert_eq!(sel.indices, meta);
        assert!(!u.needs_meta_losses(0));
    }

    #[test]
    fn random_prune_keeps_ratio_uniformly() {
        let mut rp = RandomPrune::new(200, 0.25);
        let mut rng = Pcg64::new(1);
        let mut counts = vec![0u32; 200];
        for _ in 0..400 {
            let kept = rp.on_epoch_start(0, &mut rng);
            assert_eq!(kept.len(), 150);
            for i in kept {
                counts[i as usize] += 1;
            }
        }
        // Every sample kept ~75% of the time.
        for (i, &c) in counts.iter().enumerate() {
            let p = c as f64 / 400.0;
            assert!((p - 0.75).abs() < 0.09, "idx {i}: p={p}");
        }
    }

    #[test]
    fn random_prune_varies_across_epochs() {
        let mut rp = RandomPrune::new(50, 0.5);
        let mut rng = Pcg64::new(2);
        let a = rp.on_epoch_start(0, &mut rng);
        let b = rp.on_epoch_start(1, &mut rng);
        assert_ne!(a, b);
    }
}
