//! Unified telemetry layer (DESIGN.md §11): a std-only metrics registry
//! and span tracer the whole stack reports through — engine stages,
//! kernel dispatch, data prefetch, and the serve queue — plus exporters
//! (Chrome-trace JSON, one-shot snapshots via `metrics::obs_snapshot_json`)
//! and live scraping over the serve protocol's `metrics` verb.
//!
//! Contract (the one hard rule): **telemetry never perturbs the run**.
//! Instrumentation reads clocks and bumps atomics; it never touches RNG
//! state, arithmetic, or event emission, so determinism pins hold
//! bit-for-bit at every level including `trace`. And `off` is near-free:
//! every instrumented site performs exactly one relaxed atomic load
//! before bailing (guarded by `perf_obs` in CI at ≤3% for `counters`).
//!
//! Three levels, config knob `run.telemetry = off|counters|trace`:
//!
//! * [`OFF`] — the default; sites check [`counters_on`]/[`trace_on`]
//!   (one relaxed load) and skip all work.
//! * [`COUNTERS`] — counters/gauges/histograms in the process-wide
//!   [`registry()`] accumulate; no spans.
//! * [`TRACE`] — counters plus per-stage spans in a bounded ring buffer,
//!   exportable as Chrome-trace/Perfetto JSON ([`chrome_trace_json`],
//!   CLI `--trace-out`). Spans carry a per-thread track id, so the
//!   threaded engine's workers land on distinct Perfetto tracks.
//!
//! The level is process-global (the registry is shared across
//! concurrent `Session`s — the serve scheduler's jobs aggregate into one
//! snapshot). Sessions *raise* the level from their config at run start
//! and never lower it, so one `telemetry = "off"` job cannot silently
//! blind a server that scrapes metrics; use [`set_level`] for explicit
//! control (benches, tests, the serve bootstrap).

pub mod catalog;
mod metrics;
mod trace;

pub use metrics::{registry, Counter, Gauge, Histogram, HistogramSummary, Registry, Scope};
pub use trace::{
    chrome_trace_json, clear_spans, record_elapsed, span, span_count, take_spans, SpanGuard,
    SpanRec,
};

use std::sync::atomic::{AtomicU8, Ordering};

/// Telemetry disabled: instrumented sites do one relaxed load and bail.
pub const OFF: u8 = 0;
/// Counters/gauges/histograms accumulate in the process registry.
pub const COUNTERS: u8 = 1;
/// Counters plus ring-buffered spans for Chrome-trace export.
pub const TRACE: u8 = 2;

static LEVEL: AtomicU8 = AtomicU8::new(OFF);

/// Set the process-wide telemetry level (clamped to [`TRACE`]).
pub fn set_level(level: u8) {
    LEVEL.store(level.min(TRACE), Ordering::Relaxed);
}

/// Raise the level if `level` is higher than the current one; never
/// lowers (see module docs for why sessions use this form).
pub fn raise_level(level: u8) {
    LEVEL.fetch_max(level.min(TRACE), Ordering::Relaxed);
}

/// Current process-wide telemetry level.
#[inline]
pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// True when counters (level ≥ [`COUNTERS`]) should be recorded. The
/// single gate every metric site checks first — one relaxed load.
#[inline]
pub fn counters_on() -> bool {
    level() >= COUNTERS
}

/// True when spans (level [`TRACE`]) should be recorded.
#[inline]
pub fn trace_on() -> bool {
    level() >= TRACE
}

/// Human-readable level name (snapshot/metrics responses).
pub fn level_str() -> &'static str {
    match level() {
        OFF => "off",
        COUNTERS => "counters",
        _ => "trace",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raise_never_lowers_and_set_clamps() {
        let prev = level();
        set_level(OFF);
        assert!(!counters_on() && !trace_on());
        raise_level(COUNTERS);
        assert!(counters_on() && !trace_on());
        raise_level(OFF); // no-op: raise never lowers
        assert_eq!(level(), COUNTERS);
        set_level(99); // clamped
        assert_eq!(level(), TRACE);
        assert_eq!(level_str(), "trace");
        set_level(prev);
    }
}
