//! Tab. 2: CIFAR-scale classification — 8 sampling methods × 3 workloads.
//! Paper shape to reproduce: all methods near-lossless on accuracy; batch-
//! level methods (Loss/Order/ES) show smaller savings than set-level at
//! this scale (the extra scoring FP is not negligible vs small-model BP);
//! ESWP saves the most while staying near baseline.

use crate::config::presets::{table2, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;

use super::{fmt_acc, fmt_saved, make_runtime, mean_acc, run_config, total_cost, trials};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let runs = table2(scale);
    let rec = Recorder::new("table2_cifar")?;
    let n_trials = trials(scale);

    // Group by workload (runs come ordered: 8 methods per workload).
    for chunk in runs.chunks(8) {
        let workload = chunk[0].name.split('/').nth(1).unwrap_or("?").to_string();
        table_header(
            &format!("Table 2 — {workload} (model {})", chunk[0].model),
            &["method", "acc% (Δ)", "time saved (flops-pred)"],
        );
        let mut rt = make_runtime(&chunk[0])?;
        let mut base_acc = 0.0;
        let mut base_cost = None;
        for cfg in chunk {
            let rs = run_config(cfg, rt.as_mut(), n_trials)?;
            for r in &rs {
                rec.record_result(r)?;
            }
            let acc = mean_acc(&rs);
            let cost = total_cost(&rs);
            if cfg.sampler.name() == "baseline" {
                base_acc = acc;
                base_cost = Some(cost.clone());
                println!("{:<12} | {acc:5.1}       | —", "baseline");
            } else {
                let b = base_cost.as_ref().expect("baseline first");
                println!(
                    "{:<12} | {} | {}",
                    cfg.sampler.name(),
                    fmt_acc(acc, base_acc),
                    fmt_saved(b, &cost)
                );
            }
        }
    }
    Ok(())
}
