//! Synthetic NLU classification tasks (GLUE substitute, Tab. 5/7).
//!
//! Each task plants class-indicative marker tokens into Zipf background
//! text. A per-task `signal` controls how many markers appear (≈ task
//! easiness) and `noise` controls the fraction of samples whose markers
//! are scrambled — together they reproduce GLUE's characteristic score
//! spread (CoLA hard ~55, SST-2 easy ~92, RTE small-n unstable ~74, ...).

use super::{Modality, SplitDataset, TensorDataset};
use crate::util::Pcg64;

/// Per-task difficulty profile: (marker density, scramble rate).
fn task_profile(task: &str) -> (f64, f64) {
    match task {
        "cola" => (0.10, 0.35),        // hardest: sparse, noisy signal
        "sst2" => (0.30, 0.04),        // easy sentiment
        "qnli" => (0.25, 0.06),
        "qqp" => (0.25, 0.08),
        "mnli" => (0.20, 0.12),
        "mrpc" => (0.22, 0.10),
        "rte" => (0.12, 0.25),         // hard, small data
        "stsb" => (0.20, 0.12),
        "imagenet_ft" => (0.25, 0.06), // Table-3 fine-tune substitute
        _ => (0.2, 0.1),
    }
}

pub fn generate(
    task: &str,
    n: usize,
    test_n: usize,
    vocab: usize,
    seq: usize,
    classes: usize,
    rng: &mut Pcg64,
) -> SplitDataset {
    assert!(classes >= 2 && vocab > classes * 4 + 16);
    let (signal, scramble) = task_profile(task);
    // Reserve `classes` blocks of 4 marker tokens at the top of the vocab.
    let marker_base = vocab - classes * 4;
    // Task-specific generation stream so different tasks differ even with
    // the same master seed.
    let tag = task
        .bytes()
        .fold(0u64, |acc, b| acc.wrapping_mul(31).wrapping_add(b as u64));
    let make = |n: usize, rng: &mut Pcg64| {
        let mut x = Vec::with_capacity(n * seq);
        let mut y = Vec::with_capacity(n);
        let mut difficulty = Vec::with_capacity(n);
        let mut clean = Vec::with_capacity(n);
        for i in 0..n {
            let c = (i % classes) as i32;
            let scrambled = rng.f64() < scramble;
            // Difficulty: scrambled samples are unlearnable; otherwise the
            // fewer markers a sample gets, the harder it is.
            let density = signal * rng.range_f32(0.5, 1.5) as f64;
            for _ in 0..seq {
                let u = rng.f64();
                let tok = if u < density {
                    // Marker for (possibly wrong) class.
                    let mc = if scrambled { rng.below(classes as u64) as i32 } else { c };
                    (marker_base + mc as usize * 4 + rng.below(4) as usize) as i32
                } else {
                    rng.zipf(marker_base, 1.1) as i32
                };
                x.push(tok);
            }
            y.push(c);
            clean.push(c);
            difficulty.push(if scrambled { 1.0 } else { (1.0 - density).clamp(0.0, 1.0) as f32 });
        }
        let ds = TensorDataset {
            modality: Modality::Tokens { seq },
            n,
            classes,
            x_f32: vec![],
            x_i32: x,
            y,
            y_dim: 1,
            difficulty,
            clean_class: clean,
        };
        ds.validate().expect("nlu invariants");
        ds
    };
    let mut tr = rng.fork(tag ^ 1);
    let mut te = rng.fork(tag ^ 2);
    SplitDataset { train: make(n, &mut tr), test: make(test_n, &mut te) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let mut rng = Pcg64::new(1);
        let split = generate("sst2", 64, 16, 256, 24, 2, &mut rng);
        assert_eq!(split.train.x_i32.len(), 64 * 24);
        assert!(split.train.y.iter().all(|&c| c == 0 || c == 1));
    }

    #[test]
    fn markers_correlate_with_class() {
        let mut rng = Pcg64::new(2);
        let vocab = 256;
        let classes = 2;
        let split = generate("sst2", 200, 10, vocab, 24, classes, &mut rng);
        let ds = &split.train;
        let marker_base = vocab - classes * 4;
        // Count class-0 markers in class-0 vs class-1 samples.
        let count = |want_class: i32| -> usize {
            (0..ds.n)
                .filter(|&i| ds.y[i] == want_class)
                .map(|i| {
                    ds.x_i32[i * 24..(i + 1) * 24]
                        .iter()
                        .filter(|&&t| (t as usize) >= marker_base && (t as usize) < marker_base + 4)
                        .count()
                })
                .sum()
        };
        assert!(count(0) > 3 * count(1).max(1), "{} vs {}", count(0), count(1));
    }

    #[test]
    fn cola_is_harder_than_sst2() {
        let mut rng = Pcg64::new(3);
        let cola = generate("cola", 500, 10, 256, 24, 2, &mut rng.fork(1));
        let sst2 = generate("sst2", 500, 10, 256, 24, 2, &mut rng.fork(2));
        let mean = |ds: &TensorDataset| {
            ds.difficulty.iter().map(|&d| d as f64).sum::<f64>() / ds.n as f64
        };
        assert!(mean(&cola.train) > mean(&sst2.train));
    }

    #[test]
    fn tasks_differ_under_same_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        let x = generate("qqp", 16, 4, 256, 24, 2, &mut a);
        let y = generate("rte", 16, 4, 256, 24, 2, &mut b);
        assert_ne!(x.train.x_i32, y.train.x_i32);
    }
}
