//! Epoch loader: shuffled meta-batch iteration over (possibly pruned) sets.
//!
//! Every meta-batch has exactly `meta_batch` samples so batch shapes always
//! match an AOT artifact; a ragged tail is padded by wrapping around the
//! shuffled order (each padded sample is a legitimate training sample, just
//! seen twice that epoch — standard drop-last-free practice).

use crate::util::Pcg64;

/// Iterator state for one epoch over a kept-index set.
pub struct EpochLoader {
    order: Vec<u32>,
    meta_batch: usize,
    cursor: usize,
}

impl EpochLoader {
    /// `kept` are dataset indices that survived set-level pruning.
    pub fn new(kept: &[u32], meta_batch: usize, rng: &mut Pcg64) -> Self {
        assert!(meta_batch > 0, "meta_batch must be positive");
        assert!(!kept.is_empty(), "cannot iterate an empty kept set");
        let mut order = kept.to_vec();
        rng.shuffle(&mut order);
        EpochLoader { order, meta_batch, cursor: 0 }
    }

    /// Number of meta-batches this epoch (ceil(kept / B)).
    pub fn num_batches(&self) -> usize {
        self.order.len().div_ceil(self.meta_batch)
    }

    /// Next meta-batch of exactly `meta_batch` indices, or None when done.
    pub fn next_batch(&mut self) -> Option<Vec<u32>> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let mut batch = Vec::with_capacity(self.meta_batch);
        for k in 0..self.meta_batch {
            // Wrap around for the ragged tail.
            batch.push(self.order[(self.cursor + k) % self.order.len()]);
        }
        self.cursor += self.meta_batch;
        Some(batch)
    }
}

/// Background prefetcher: assembles the next meta-batch's index list on a
/// worker thread while the current step executes. Index assembly is cheap,
/// but the same channel pattern covers future gather-offload; it also
/// keeps the trainer loop allocation-free on the happy path.
pub struct Prefetcher {
    rx: Option<std::sync::mpsc::Receiver<Vec<u32>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    pub fn spawn(kept: Vec<u32>, meta_batch: usize, mut rng: Pcg64, depth: usize) -> Self {
        let (tx, rx) = std::sync::mpsc::sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            let mut loader = EpochLoader::new(&kept, meta_batch, &mut rng);
            while let Some(batch) = loader.next_batch() {
                if tx.send(batch).is_err() {
                    return; // consumer dropped
                }
            }
        });
        Prefetcher { rx: Some(rx), handle: Some(handle) }
    }

    pub fn next(&mut self) -> Option<Vec<u32>> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Close the channel first so a worker blocked on send() observes
        // the disconnect, then join.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_indices_once_when_divisible() {
        let mut rng = Pcg64::new(1);
        let kept: Vec<u32> = (0..64).collect();
        let mut loader = EpochLoader::new(&kept, 16, &mut rng);
        let mut seen = Vec::new();
        while let Some(b) = loader.next_batch() {
            assert_eq!(b.len(), 16);
            seen.extend(b);
        }
        seen.sort_unstable();
        assert_eq!(seen, kept);
    }

    #[test]
    fn ragged_tail_pads_by_wraparound() {
        let mut rng = Pcg64::new(2);
        let kept: Vec<u32> = (0..10).collect();
        let mut loader = EpochLoader::new(&kept, 4, &mut rng);
        assert_eq!(loader.num_batches(), 3);
        let mut count = 0;
        let mut seen = std::collections::HashSet::new();
        while let Some(b) = loader.next_batch() {
            assert_eq!(b.len(), 4);
            seen.extend(b);
            count += 1;
        }
        assert_eq!(count, 3);
        assert_eq!(seen.len(), 10, "every sample seen at least once");
    }

    #[test]
    fn shuffles_between_epochs() {
        let kept: Vec<u32> = (0..32).collect();
        let mut rng = Pcg64::new(3);
        let a: Vec<u32> = EpochLoader::new(&kept, 32, &mut rng).next_batch().unwrap();
        let b: Vec<u32> = EpochLoader::new(&kept, 32, &mut rng).next_batch().unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn respects_kept_subset() {
        let mut rng = Pcg64::new(4);
        let kept = vec![3u32, 7, 11, 15];
        let mut loader = EpochLoader::new(&kept, 2, &mut rng);
        while let Some(b) = loader.next_batch() {
            for i in b {
                assert!(kept.contains(&i));
            }
        }
    }

    #[test]
    fn prefetcher_yields_same_multiset_as_loader() {
        let kept: Vec<u32> = (0..40).collect();
        let mut pf = Prefetcher::spawn(kept.clone(), 8, Pcg64::new(5), 2);
        let mut seen = Vec::new();
        while let Some(b) = pf.next() {
            seen.extend(b);
        }
        seen.sort_unstable();
        assert_eq!(seen, kept);
    }

    #[test]
    fn prefetcher_drop_mid_stream_is_clean() {
        let kept: Vec<u32> = (0..1000).collect();
        let mut pf = Prefetcher::spawn(kept, 8, Pcg64::new(6), 2);
        let _ = pf.next();
        drop(pf); // must not deadlock or panic
    }
}
