//! The serve wire protocol: newline-delimited JSON over TCP.
//!
//! Every request is one JSON object on one line with a `"cmd"` tag;
//! every response is one JSON object on one line with an `"ok"` bool.
//! The `events` command switches the connection into streaming mode:
//! the server replays the job's event backlog, then forwards live
//! events until the job reaches a terminal state, then sends a final
//! `ok` line and returns to request/response mode.

use crate::util::json::{obj, s, Json};

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Enqueue a run. `config_toml` is a full run config document (the
    /// same TOML `evosample train --config` takes); `sampler` optionally
    /// overrides `[sampler]` with a registry name at its defaults.
    Submit {
        config_toml: String,
        name: Option<String>,
        sampler: Option<String>,
        job_id: Option<String>,
    },
    /// Report one job (or all jobs when `job` is absent).
    Status { job: Option<String> },
    /// Stream a job's event backlog + live events until it finishes.
    Events { job: String },
    /// Cooperatively cancel a queued or running job.
    Cancel { job: String },
    /// Scrape the server's telemetry: the process metrics snapshot plus
    /// queue/kernel occupancy, and per-job selection health (one job
    /// when `job` is given, every known job otherwise).
    Metrics { job: Option<String> },
    /// Stop the server: `drain` finishes queued+running jobs first,
    /// `abort` interrupts running jobs at the next epoch boundary
    /// (checkpoints retained, so a restart resumes them) and leaves
    /// queued jobs unclaimed for the next server life's rescan.
    Shutdown { abort: bool },
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| format!("bad request json: {e}"))?;
        let cmd = j.get("cmd").and_then(Json::as_str).ok_or("missing \"cmd\"")?;
        let get_str = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        match cmd {
            "submit" => Ok(Request::Submit {
                config_toml: get_str("config")
                    .ok_or("submit needs \"config\" (a run-config TOML document)")?,
                name: get_str("name"),
                sampler: get_str("sampler"),
                job_id: get_str("job_id"),
            }),
            "status" => Ok(Request::Status { job: get_str("job") }),
            "events" => {
                Ok(Request::Events { job: get_str("job").ok_or("events needs \"job\"")? })
            }
            "cancel" => {
                Ok(Request::Cancel { job: get_str("job").ok_or("cancel needs \"job\"")? })
            }
            "metrics" => Ok(Request::Metrics { job: get_str("job") }),
            "shutdown" => match get_str("mode").as_deref().unwrap_or("drain") {
                "drain" => Ok(Request::Shutdown { abort: false }),
                "abort" => Ok(Request::Shutdown { abort: true }),
                other => Err(format!("unknown shutdown mode {other:?}")),
            },
            other => Err(format!("unknown cmd {other:?}")),
        }
    }
}

/// The serve-side job lifecycle event names, as they appear in `event`
/// fields on the wire and in job records — alongside the engine's own
/// events (snake-cased `api::events::Event` variants) that stream
/// through unchanged.
///
/// This is the authoritative list evolint's `registry/event-names` rule
/// checks serve instrumentation sites against (DESIGN.md §13): an event
/// name typo'd at an emission site would silently split a job's history
/// across two names for every consumer replaying the backlog.
pub const LIFECYCLE_EVENTS: &[&str] = &[
    "queued",    // accepted into the queue (server)
    "admitted",  // claimed by the scheduler, about to run (job)
    "state",     // explicit state-transition record (job)
    "requeued",  // released back to pending after an interrupted claim (server)
    "retrying",  // worker error, scheduled for another attempt (scheduler)
    "restarted", // resumed from checkpoint after a server restart (scheduler)
    "resumed",   // picked up mid-run from a rescan (scheduler)
];

/// `{"ok":true, ...fields}`.
pub fn ok_response(fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    obj(all)
}

/// `{"ok":false,"error":msg}`.
pub fn err_response(msg: &str) -> Json {
    obj(vec![("ok", Json::Bool(false)), ("error", s(msg))])
}

/// Admission-control shed: `{"ok":false,"rejected":true,"reason":..}`.
pub fn rejected_response(reason: &str) -> Json {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("rejected", Json::Bool(true)),
        ("reason", s(reason)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_submit_with_embedded_toml() {
        let toml = "[run]\nmodel = \"mlp\"\n";
        let line = obj(vec![
            ("cmd", s("submit")),
            ("config", s(toml)),
            ("sampler", s("es")),
        ])
        .to_string_compact();
        match Request::parse(&line).unwrap() {
            Request::Submit { config_toml, sampler, name, job_id } => {
                assert_eq!(config_toml, toml, "TOML text round-trips through the wire");
                assert_eq!(sampler.as_deref(), Some("es"));
                assert_eq!(name, None);
                assert_eq!(job_id, None);
            }
            other => panic!("wrong request {other:?}"),
        }
    }

    #[test]
    fn parses_remaining_commands() {
        assert_eq!(
            Request::parse(r#"{"cmd":"status"}"#).unwrap(),
            Request::Status { job: None }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"status","job":"j1"}"#).unwrap(),
            Request::Status { job: Some("j1".into()) }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"events","job":"j1"}"#).unwrap(),
            Request::Events { job: "j1".into() }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"cancel","job":"j1"}"#).unwrap(),
            Request::Cancel { job: "j1".into() }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"metrics"}"#).unwrap(),
            Request::Metrics { job: None }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"metrics","job":"j1"}"#).unwrap(),
            Request::Metrics { job: Some("j1".into()) }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"shutdown"}"#).unwrap(),
            Request::Shutdown { abort: false }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"shutdown","mode":"abort"}"#).unwrap(),
            Request::Shutdown { abort: true }
        );
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"cmd":"explode"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit"}"#).is_err(), "submit needs config");
        assert!(Request::parse(r#"{"cmd":"events"}"#).is_err(), "events needs job");
        assert!(Request::parse(r#"{"cmd":"shutdown","mode":"later"}"#).is_err());
    }

    #[test]
    fn lifecycle_event_names_are_unique_and_snake_case() {
        for name in LIFECYCLE_EVENTS {
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase() || c == '_'),
                "wire event names are snake_case: {name:?}"
            );
        }
        let mut sorted: Vec<&str> = LIFECYCLE_EVENTS.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), LIFECYCLE_EVENTS.len(), "no duplicate names");
    }

    #[test]
    fn response_builders_tag_ok() {
        let r = ok_response(vec![("job", s("j1"))]);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("job").and_then(Json::as_str), Some("j1"));
        let r = err_response("boom");
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = rejected_response("queue_full");
        assert_eq!(r.get("rejected"), Some(&Json::Bool(true)));
        assert_eq!(r.get("reason").and_then(Json::as_str), Some("queue_full"));
    }
}
