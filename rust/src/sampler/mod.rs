//! Dynamic data selection — the paper's contribution (ES/ESWP) plus every
//! baseline it compares against (Tab. 1).
//!
//! The trainer drives samplers through one trait with four hooks:
//!
//! 1. `on_epoch_start` — *set-level* selection: return the kept dataset
//!    indices for this epoch (pruning methods shrink the set; batch-level
//!    methods return everything).
//! 2. `needs_meta_losses` — whether this epoch's steps require a scoring
//!    forward pass over the meta-batch (batch-level methods only; this is
//!    the "extra FP" of the paper's §3.3 cost analysis).
//! 3. `observe_meta` / `observe_train` — fresh per-sample losses, either
//!    from the scoring FP (meta) or as a free byproduct of the training
//!    step (train). ES updates its Eq. 3.1 state from both, so the
//!    annealing epochs double as weight warm-up exactly as in Alg. 1.
//! 4. `select` — *batch-level* selection of the BP mini-batch from the
//!    meta-batch, with per-sample gradient weights (InfoBatch's rescale).

pub mod analysis;
pub mod annealing;
pub mod evolved;
pub mod infobatch;
pub mod kakurenbo;
pub mod loss_based;
pub mod ordered;
pub mod ucb;
pub mod uniform;
pub mod weights;

use crate::config::SamplerConfig;
use crate::util::Pcg64;

/// The mini-batch chosen for the backward pass.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    /// Dataset indices to run BP on (subset or all of the meta-batch).
    pub indices: Vec<u32>,
    /// Per-sample gradient weights (all 1.0 unless the method rescales).
    pub weights: Vec<f32>,
}

impl Selection {
    pub fn unweighted(indices: Vec<u32>) -> Self {
        let weights = vec![1.0; indices.len()];
        Selection { indices, weights }
    }
}

/// One dynamic sampling method. See module docs for the call protocol.
pub trait Sampler: Send {
    fn name(&self) -> &'static str;

    /// Set-level selection at epoch start; returns kept dataset indices.
    fn on_epoch_start(&mut self, _epoch: usize, _rng: &mut Pcg64) -> Vec<u32> {
        (0..self.n() as u32).collect()
    }

    /// Does this epoch's step loop need a scoring FP over meta-batches?
    fn needs_meta_losses(&self, _epoch: usize) -> bool {
        false
    }

    /// Fresh losses from the scoring FP on a meta-batch.
    fn observe_meta(&mut self, _indices: &[u32], _losses: &[f32], _epoch: usize) {}

    /// Fresh losses from the training step itself (free, no extra FP).
    fn observe_train(&mut self, _indices: &[u32], _losses: &[f32], _epoch: usize) {}

    /// Batch-level selection of `mini` samples from the meta-batch.
    /// Default: train on the whole meta-batch, unweighted.
    fn select(&mut self, meta: &[u32], _mini: usize, _epoch: usize, _rng: &mut Pcg64) -> Selection {
        Selection::unweighted(meta.to_vec())
    }

    /// Dataset size this sampler was built for.
    fn n(&self) -> usize;
}

/// Instantiate a sampler from config for a dataset of `n` samples trained
/// for `epochs` epochs.
pub fn build(cfg: &SamplerConfig, n: usize, epochs: usize) -> Box<dyn Sampler> {
    match cfg {
        SamplerConfig::Uniform => Box::new(uniform::Uniform::new(n)),
        SamplerConfig::Loss => Box::new(loss_based::LossSampler::new(n)),
        SamplerConfig::Ordered => Box::new(ordered::OrderedSgd::new(n)),
        SamplerConfig::Es { beta1, beta2, anneal_frac } => Box::new(evolved::Evolved::new(
            n,
            epochs,
            *beta1,
            *beta2,
            *anneal_frac,
            0.0,
        )),
        SamplerConfig::Eswp { beta1, beta2, anneal_frac, prune_ratio } => Box::new(
            evolved::Evolved::new(n, epochs, *beta1, *beta2, *anneal_frac, *prune_ratio),
        ),
        SamplerConfig::InfoBatch { prune_ratio, anneal_frac } => {
            Box::new(infobatch::InfoBatch::new(n, epochs, *prune_ratio, *anneal_frac))
        }
        SamplerConfig::Kakurenbo { prune_ratio, conf_threshold } => {
            Box::new(kakurenbo::Kakurenbo::new(n, *prune_ratio, *conf_threshold))
        }
        SamplerConfig::Ucb { prune_ratio, decay, c } => {
            Box::new(ucb::Ucb::new(n, *prune_ratio, *decay, *c))
        }
        SamplerConfig::RandomPrune { prune_ratio } => {
            Box::new(uniform::RandomPrune::new(n, *prune_ratio))
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    Baseline,
    BatchLevel,
    SetLevel,
    Both,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerConfig as SC;

    #[test]
    fn build_constructs_every_method() {
        let cfgs = [
            SC::Uniform,
            SC::Loss,
            SC::Ordered,
            SC::es_default(),
            SC::eswp_default(),
            SC::infobatch_default(),
            SC::kakurenbo_default(),
            SC::ucb_default(),
            SC::RandomPrune { prune_ratio: 0.2 },
        ];
        for cfg in cfgs {
            let s = build(&cfg, 100, 10);
            assert_eq!(s.n(), 100);
            assert_eq!(s.name(), cfg.name());
        }
    }

    #[test]
    fn default_epoch_start_keeps_everything() {
        let mut s = build(&SC::Uniform, 50, 10);
        let kept = s.on_epoch_start(0, &mut Pcg64::new(0));
        assert_eq!(kept, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn selection_unweighted_has_unit_weights() {
        let sel = Selection::unweighted(vec![3, 1]);
        assert_eq!(sel.weights, vec![1.0, 1.0]);
    }
}
