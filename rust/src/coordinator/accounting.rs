//! Cost accounting: turns timers + sample counts into the paper's
//! "Time ↓" metric, plus an analytic FLOPs model for cross-checking.
//!
//! The paper's §3.3 argument: BP dominates (≈ 2× FP FLOPs; a full training
//! step ≈ 3× a forward), so cutting BP from B to b samples while paying an
//! extra B-sample FP still wins when b ≪ B. The analytic model below
//! encodes exactly that and is validated against measured wall-clock in
//! the integration tests and EXPERIMENTS.md.

use crate::util::timer::{phase, PhaseTimers};

/// A training step costs ~3× the forward FLOPs of the same batch
/// (forward + backward ≈ 2× forward).
pub const TRAIN_STEP_FWD_MULTIPLE: u64 = 3;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostSummary {
    /// Samples that went through the scoring forward pass.
    pub fp_samples: u64,
    /// Number of scoring-FP invocations. With frequency tuning
    /// (`run.score_every = k`, DESIGN.md §8) this is ~steps/k, and
    /// `fp_samples`/`fp_flops` shrink by the same factor — the paper's
    /// amortized "extra FP" cost.
    pub fp_passes: u64,
    /// Samples that went through back-propagation.
    pub bp_samples: u64,
    /// Number of train_step invocations (≠ steps under grad accumulation).
    pub bp_passes: u64,
    /// Analytic FLOPs: scoring FPs.
    pub fp_flops: u64,
    /// Analytic FLOPs: training steps (fwd+bwd).
    pub bp_flops: u64,
    /// Measured seconds per phase.
    pub scoring_s: f64,
    pub train_s: f64,
    pub select_s: f64,
    pub data_s: f64,
    pub prune_s: f64,
    pub sync_s: f64,
    pub eval_s: f64,
}

impl CostSummary {
    pub fn from_run(
        timers: &PhaseTimers,
        fp_samples: u64,
        bp_samples: u64,
        bp_passes: u64,
        flops_per_sample_fwd: u64,
    ) -> CostSummary {
        CostSummary {
            fp_samples,
            fp_passes: 0,
            bp_samples,
            bp_passes,
            fp_flops: fp_samples * flops_per_sample_fwd,
            bp_flops: bp_samples * flops_per_sample_fwd * TRAIN_STEP_FWD_MULTIPLE,
            scoring_s: timers.get(phase::SCORING_FP).as_secs_f64(),
            train_s: timers.get(phase::TRAIN_BP).as_secs_f64(),
            select_s: timers.get(phase::SELECT).as_secs_f64(),
            data_s: timers.get(phase::DATA).as_secs_f64(),
            prune_s: timers.get(phase::PRUNE).as_secs_f64(),
            sync_s: timers.get(phase::SYNC).as_secs_f64(),
            eval_s: timers.get(phase::EVAL).as_secs_f64(),
        }
    }

    /// Field-wise sum of another run's costs (counts, flops, measured
    /// seconds) — the single accumulator every multi-run total routes
    /// through, so a newly added field cannot silently miss a hand-rolled
    /// copy of this loop.
    pub fn accumulate(&mut self, other: &CostSummary) {
        self.fp_samples += other.fp_samples;
        self.fp_passes += other.fp_passes;
        self.bp_samples += other.bp_samples;
        self.bp_passes += other.bp_passes;
        self.fp_flops += other.fp_flops;
        self.bp_flops += other.bp_flops;
        self.scoring_s += other.scoring_s;
        self.train_s += other.train_s;
        self.select_s += other.select_s;
        self.data_s += other.data_s;
        self.prune_s += other.prune_s;
        self.sync_s += other.sync_s;
        self.eval_s += other.eval_s;
    }

    /// Attach the scoring-FP invocation count (kept out of `from_run` so
    /// the historical signature — and the pre-refactor reference loop
    /// that pins it — stays untouched).
    pub fn with_fp_passes(mut self, fp_passes: u64) -> CostSummary {
        self.fp_passes = fp_passes;
        self
    }

    /// Total *training* seconds (what the paper's Time columns measure —
    /// eval excluded, exactly as wall-clock comparisons in the paper).
    /// Synchronization rounds count as training time (§D.5: the sync is
    /// on the critical path of distributed pre-training).
    pub fn train_wall_s(&self) -> f64 {
        self.scoring_s + self.train_s + self.select_s + self.data_s + self.prune_s + self.sync_s
    }

    /// Total analytic FLOPs (scoring + training).
    pub fn total_flops(&self) -> u64 {
        self.fp_flops + self.bp_flops
    }

    /// Predicted time ratio vs a baseline using the FLOPs model.
    pub fn flops_ratio_vs(&self, base: &CostSummary) -> f64 {
        self.total_flops() as f64 / base.total_flops() as f64
    }
}

/// The paper's "Time ↓" (saved wall-clock) in percent, method vs baseline.
pub fn saved_time_pct(base: &CostSummary, method: &CostSummary) -> f64 {
    let b = base.train_wall_s();
    if b <= 0.0 {
        return 0.0;
    }
    100.0 * (1.0 - method.train_wall_s() / b)
}

/// Predicted saved time from the analytic FLOPs model (for the same
/// workload shape). Used to sanity-check measurements and to report
/// "expected" columns where wall-clock is too noisy at smoke scale.
pub fn predicted_saved_time_pct(base: &CostSummary, method: &CostSummary) -> f64 {
    100.0 * (1.0 - method.flops_ratio_vs(base))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn summary(fp: u64, bp: u64) -> CostSummary {
        CostSummary::from_run(&PhaseTimers::new(), fp, bp, bp / 8, 100)
    }

    #[test]
    fn flops_model_matches_paper_argument() {
        // Baseline: BP on B=128 per step. ES: FP on 128 + BP on 32.
        let steps = 1000u64;
        let base = summary(0, 128 * steps);
        let es = summary(128 * steps, 32 * steps);
        // base: 128*3 = 384 units/step; es: 128 + 32*3 = 224 units/step.
        let pred = predicted_saved_time_pct(&base, &es);
        assert!((pred - (1.0 - 224.0 / 384.0) * 100.0).abs() < 1e-9, "pred={pred}");
        assert!(pred > 40.0, "ES should save >40% FLOPs at b/B=25%");
    }

    #[test]
    fn accumulate_sums_every_field() {
        let mut t_a = PhaseTimers::new();
        t_a.add(crate::util::timer::phase::SYNC, Duration::from_secs(2));
        t_a.add(crate::util::timer::phase::EVAL, Duration::from_secs(3));
        let a = CostSummary::from_run(&t_a, 10, 20, 5, 100).with_fp_passes(2);
        let mut total = CostSummary::default();
        total.accumulate(&a);
        total.accumulate(&a);
        assert_eq!(total.fp_samples, 20);
        assert_eq!(total.fp_passes, 4);
        assert_eq!(total.bp_samples, 40);
        assert_eq!(total.bp_passes, 10);
        assert_eq!(total.fp_flops, 2 * 10 * 100);
        assert_eq!(total.bp_flops, 2 * 20 * 100 * TRAIN_STEP_FWD_MULTIPLE);
        assert!((total.sync_s - 4.0).abs() < 1e-9);
        assert!((total.eval_s - 6.0).abs() < 1e-9);
    }

    #[test]
    fn accumulate_and_fp_passes_round_trip_property() {
        use crate::prop_assert;
        use crate::util::timer::phase;
        crate::util::proptest::check("cost accumulate round-trip", 200, |g| {
            let k = g.usize_in(1, 8);
            let mut parts: Vec<CostSummary> = Vec::with_capacity(k);
            for _ in 0..k {
                let mut t = PhaseTimers::new();
                for label in [
                    phase::SCORING_FP,
                    phase::TRAIN_BP,
                    phase::SELECT,
                    phase::DATA,
                    phase::PRUNE,
                    phase::SYNC,
                    phase::EVAL,
                ] {
                    t.add(label, Duration::from_secs_f64(g.f64_in(0.0, 2.0)));
                }
                let fp = g.usize_in(0, 10_000) as u64;
                let bp = g.usize_in(0, 10_000) as u64;
                let passes = g.usize_in(0, 512) as u64;
                let flops = g.usize_in(1, 1_000) as u64;
                let s = CostSummary::from_run(&t, fp, bp, bp / 8, flops);
                prop_assert!(s.fp_passes == 0, "from_run must leave fp_passes unset");
                let s = s.with_fp_passes(passes);
                prop_assert!(s.fp_passes == passes, "with_fp_passes must round-trip");
                prop_assert!(
                    s.fp_flops == fp * flops,
                    "with_fp_passes must not touch fp_flops"
                );
                parts.push(s);
            }
            let mut total = CostSummary::default();
            for p in &parts {
                total.accumulate(p);
            }
            let sum_u = |f: fn(&CostSummary) -> u64| parts.iter().map(f).sum::<u64>();
            prop_assert!(total.fp_samples == sum_u(|s| s.fp_samples), "fp_samples");
            prop_assert!(total.fp_passes == sum_u(|s| s.fp_passes), "fp_passes");
            prop_assert!(total.bp_samples == sum_u(|s| s.bp_samples), "bp_samples");
            prop_assert!(total.bp_passes == sum_u(|s| s.bp_passes), "bp_passes");
            prop_assert!(total.total_flops() == sum_u(|s| s.total_flops()), "flops");
            let wall: f64 = parts.iter().map(CostSummary::train_wall_s).sum();
            prop_assert!(
                (total.train_wall_s() - wall).abs() < 1e-6 * (1.0 + wall),
                "train_wall_s: accumulated {} vs summed {wall}",
                total.train_wall_s()
            );
            let eval: f64 = parts.iter().map(|s| s.eval_s).sum();
            prop_assert!((total.eval_s - eval).abs() < 1e-9, "eval_s");
            Ok(())
        });
    }

    #[test]
    fn frequency_tuning_amortizes_scoring_flops() {
        // ES at score_every = k scores ⌈steps/k⌉ meta-batches: fp_flops
        // shrink k-fold while bp_flops are unchanged, so the predicted
        // saving strictly improves with k.
        let steps = 1000u64;
        let base = summary(0, 128 * steps);
        let es_k1 = summary(128 * steps, 32 * steps).with_fp_passes(steps);
        let es_k4 = summary(128 * steps / 4, 32 * steps).with_fp_passes(steps / 4);
        assert_eq!(es_k4.fp_flops * 4, es_k1.fp_flops);
        assert_eq!(es_k4.bp_flops, es_k1.bp_flops);
        assert!(
            predicted_saved_time_pct(&base, &es_k4) > predicted_saved_time_pct(&base, &es_k1)
        );
        assert_eq!(es_k4.fp_passes * 4, es_k1.fp_passes);
    }

    #[test]
    fn eswp_saves_more_than_es() {
        let steps = 1000u64;
        let es = summary(128 * steps, 32 * steps);
        // ESWP at r=0.2: 20% fewer steps entirely.
        let eswp = summary(128 * steps * 8 / 10, 32 * steps * 8 / 10);
        let base = summary(0, 128 * steps);
        assert!(
            predicted_saved_time_pct(&base, &eswp) > predicted_saved_time_pct(&base, &es)
        );
    }

    #[test]
    fn saved_time_uses_training_phases_only() {
        let mut t_base = PhaseTimers::new();
        t_base.add(crate::util::timer::phase::TRAIN_BP, Duration::from_secs(10));
        t_base.add(crate::util::timer::phase::EVAL, Duration::from_secs(100));
        let base = CostSummary::from_run(&t_base, 0, 0, 0, 1);

        let mut t_m = PhaseTimers::new();
        t_m.add(crate::util::timer::phase::TRAIN_BP, Duration::from_secs(5));
        t_m.add(crate::util::timer::phase::EVAL, Duration::from_secs(500));
        let m = CostSummary::from_run(&t_m, 0, 0, 0, 1);

        assert!((saved_time_pct(&base, &m) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn zero_baseline_is_safe() {
        let z = summary(0, 0);
        assert_eq!(saved_time_pct(&z, &z), 0.0);
    }
}
