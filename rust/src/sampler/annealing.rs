//! Annealing window (paper Alg. 1): run standard batched sampling — no
//! data selection — during the first and last `anneal_frac` of epochs.
//! The leading window warm-starts the score tables (losses still observed
//! from training steps); the trailing window removes selection bias before
//! convergence, following InfoBatch (Qin et al. 2024).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Annealing {
    /// First epoch (inclusive) where selection is active.
    pub start: usize,
    /// First epoch (exclusive) after which selection is disabled again.
    pub end: usize,
}

impl Annealing {
    /// `frac` of `epochs` is annealed at each side (ceil, min 0).
    pub fn new(epochs: usize, frac: f64) -> Self {
        let k = (epochs as f64 * frac).ceil() as usize;
        // Degenerate configs (window swallows everything) => never active.
        if 2 * k >= epochs {
            if frac > 0.0 {
                return Annealing { start: epochs, end: epochs };
            }
        }
        Annealing { start: k, end: epochs - k }
    }

    /// No annealing at all.
    pub fn none(epochs: usize) -> Self {
        Annealing { start: 0, end: epochs }
    }

    /// Is data selection active at `epoch`?
    pub fn active(&self, epoch: usize) -> bool {
        (self.start..self.end).contains(&epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_percent_of_twenty_is_one_epoch_each_side() {
        let a = Annealing::new(20, 0.05);
        assert!(!a.active(0));
        assert!(a.active(1));
        assert!(a.active(18));
        assert!(!a.active(19));
    }

    #[test]
    fn zero_frac_is_always_active() {
        let a = Annealing::new(10, 0.0);
        assert!((0..10).all(|e| a.active(e)));
    }

    #[test]
    fn window_swallowing_everything_disables_selection() {
        let a = Annealing::new(2, 0.5);
        assert!((0..2).all(|e| !a.active(e)));
        let a = Annealing::new(1, 0.05);
        assert!(!a.active(0));
    }

    #[test]
    fn none_matches_zero_frac() {
        assert_eq!(Annealing::none(7), Annealing::new(7, 0.0));
    }

    #[test]
    fn fractional_windows_round_up() {
        // 0.05 * 30 = 1.5 -> 2 epochs annealed each side.
        let a = Annealing::new(30, 0.05);
        assert_eq!(a.start, 2);
        assert_eq!(a.end, 28);
    }
}
