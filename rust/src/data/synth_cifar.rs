//! SynthCIFAR: procedural CIFAR-like image classification data.
//!
//! Each class gets a smooth low-frequency prototype image (random coarse
//! 8×8 pattern bilinearly upsampled to 32×32×3). A sample is its class
//! prototype plus per-sample noise whose magnitude follows a difficulty
//! distribution: most samples are easy (low noise), a `hard_frac` tail is
//! heavily corrupted, and `label_noise` of samples get a wrong label
//! outright. This reproduces the structure that makes dynamic data
//! selection interesting on real CIFAR: a learnable easy core, hard
//! informative samples with persistently higher loss, and noisy samples
//! whose loss never decreases.

use super::{Modality, SplitDataset, TensorDataset};
use crate::util::Pcg64;

pub const IMG: usize = 32;
pub const DIM: usize = IMG * IMG * 3;
const COARSE: usize = 8;

/// Build one smooth class prototype (flat [32*32*3], values roughly ±1).
fn prototype(rng: &mut Pcg64) -> Vec<f32> {
    // Random coarse grid per channel, bilinear upsample.
    let mut out = vec![0.0f32; DIM];
    for ch in 0..3 {
        let coarse: Vec<f32> = (0..COARSE * COARSE).map(|_| rng.normal()).collect();
        for y in 0..IMG {
            for x in 0..IMG {
                let fy = y as f32 / IMG as f32 * (COARSE - 1) as f32;
                let fx = x as f32 / IMG as f32 * (COARSE - 1) as f32;
                let (y0, x0) = (fy as usize, fx as usize);
                let (y1, x1) = ((y0 + 1).min(COARSE - 1), (x0 + 1).min(COARSE - 1));
                let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
                let v = coarse[y0 * COARSE + x0] * (1.0 - dy) * (1.0 - dx)
                    + coarse[y0 * COARSE + x1] * (1.0 - dy) * dx
                    + coarse[y1 * COARSE + x0] * dy * (1.0 - dx)
                    + coarse[y1 * COARSE + x1] * dy * dx;
                out[(y * IMG + x) * 3 + ch] = v;
            }
        }
    }
    out
}

/// Draw a per-sample difficulty in [0, 1]: easy bulk + hard tail.
fn draw_difficulty(rng: &mut Pcg64, hard_frac: f64) -> f32 {
    if (rng.f64()) < hard_frac {
        rng.range_f32(0.6, 1.0) // hard tail
    } else {
        rng.range_f32(0.0, 0.4) // easy bulk
    }
}

fn make_split(
    n: usize,
    classes: usize,
    label_noise: f64,
    hard_frac: f64,
    protos: &[Vec<f32>],
    rng: &mut Pcg64,
) -> TensorDataset {
    let mut x = Vec::with_capacity(n * DIM);
    let mut y = Vec::with_capacity(n);
    let mut difficulty = Vec::with_capacity(n);
    let mut clean = Vec::with_capacity(n);
    for i in 0..n {
        let c = (i % classes) as i32; // balanced classes
        let d = draw_difficulty(rng, hard_frac);
        // Noise std grows with difficulty: easy ≈ 0.35σ, hard ≈ 1.4σ.
        let sigma = 0.3 + 1.2 * d;
        let proto = &protos[c as usize];
        for &p in proto {
            x.push(p + sigma * rng.normal());
        }
        let noisy = rng.f64() < label_noise;
        let label = if noisy {
            // A wrong label chosen uniformly among the others.
            let mut l = rng.below(classes as u64) as i32;
            if l == c {
                l = (l + 1) % classes as i32;
            }
            l
        } else {
            c
        };
        y.push(label);
        clean.push(c);
        // Label-noise samples are effectively unlearnable: difficulty 1.
        difficulty.push(if noisy { 1.0 } else { d });
    }
    let ds = TensorDataset {
        modality: Modality::Float { dim: DIM },
        n,
        classes,
        x_f32: x,
        x_i32: vec![],
        y,
        y_dim: 1,
        difficulty,
        clean_class: clean,
    };
    ds.validate().expect("synth_cifar invariants");
    ds
}

/// Generate a train/test split. Test data is clean-labeled (standard
/// benchmark practice: label noise only corrupts training data).
pub fn generate(
    n: usize,
    test_n: usize,
    classes: usize,
    label_noise: f64,
    hard_frac: f64,
    rng: &mut Pcg64,
) -> SplitDataset {
    assert!(classes >= 2, "need >= 2 classes");
    let mut proto_rng = rng.fork(0x9107);
    let protos: Vec<Vec<f32>> = (0..classes).map(|_| prototype(&mut proto_rng)).collect();
    let mut train_rng = rng.fork(0x7e57 + 1);
    let mut test_rng = rng.fork(0x7e57 + 2);
    SplitDataset {
        train: make_split(n, classes, label_noise, hard_frac, &protos, &mut train_rng),
        test: make_split(test_n, classes, 0.0, hard_frac, &protos, &mut test_rng),
    }
}

/// Unlabeled images for MAE pre-training: mixture of smooth prototypes so
/// there is structure to reconstruct, with difficulty-scaled noise.
pub fn generate_unlabeled(n: usize, test_n: usize, dim: usize, rng: &mut Pcg64) -> SplitDataset {
    let k = 16; // latent "scene" prototypes
    let mut proto_rng = rng.fork(0x9108);
    let protos: Vec<Vec<f32>> = (0..k)
        .map(|_| {
            let p = prototype(&mut proto_rng);
            // Resize the flat 3072 prototype to `dim` by tiling/truncation.
            (0..dim).map(|i| p[i % DIM]).collect()
        })
        .collect();
    let make = |n: usize, rng: &mut Pcg64| {
        let mut x = Vec::with_capacity(n * dim);
        let mut difficulty = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(k as u64) as usize;
            let d = draw_difficulty(rng, 0.2);
            let sigma = 0.2 + 0.8 * d;
            for &p in &protos[c] {
                x.push(p + sigma * rng.normal());
            }
            difficulty.push(d);
        }
        let ds = TensorDataset {
            modality: Modality::Float { dim },
            n,
            classes: 0,
            x_f32: x,
            x_i32: vec![],
            y: vec![0; n],
            y_dim: 1,
            difficulty,
            clean_class: vec![0; n],
        };
        ds.validate().expect("mae invariants");
        ds
    };
    let mut tr = rng.fork(1);
    let mut te = rng.fork(2);
    SplitDataset { train: make(n, &mut tr), test: make(test_n, &mut te) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math;

    #[test]
    fn shapes_and_balance() {
        let mut rng = Pcg64::new(1);
        let split = generate(100, 20, 10, 0.0, 0.2, &mut rng);
        assert_eq!(split.train.n, 100);
        assert_eq!(split.train.x_f32.len(), 100 * DIM);
        // Balanced: each class has exactly 10 train samples.
        for c in 0..10 {
            assert_eq!(split.train.y.iter().filter(|&&y| y == c).count(), 10);
        }
    }

    #[test]
    fn label_noise_rate_applied() {
        let mut rng = Pcg64::new(2);
        let split = generate(2000, 10, 10, 0.2, 0.2, &mut rng);
        let flipped = split
            .train
            .y
            .iter()
            .zip(&split.train.clean_class)
            .filter(|(a, b)| a != b)
            .count();
        let rate = flipped as f64 / 2000.0;
        assert!((rate - 0.2).abs() < 0.04, "rate={rate}");
        // Test split is always clean.
        assert_eq!(
            split.test.y, split.test.clean_class,
            "test labels must be clean"
        );
    }

    #[test]
    fn hard_tail_exists() {
        let mut rng = Pcg64::new(3);
        let split = generate(1000, 10, 10, 0.0, 0.25, &mut rng);
        let hard = split.train.difficulty.iter().filter(|&&d| d >= 0.6).count();
        let rate = hard as f64 / 1000.0;
        assert!((rate - 0.25).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn classes_are_separable_in_feature_space() {
        // Same-class samples must be closer than cross-class on average —
        // otherwise no model could learn and selection results would be
        // meaningless noise.
        let mut rng = Pcg64::new(4);
        let split = generate(200, 40, 4, 0.0, 0.0, &mut rng);
        let ds = &split.train;
        let dist = |a: usize, b: usize| -> f64 {
            (0..DIM)
                .map(|j| (ds.x_f32[a * DIM + j] - ds.x_f32[b * DIM + j]) as f64)
                .map(|d| d * d)
                .sum::<f64>()
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for a in 0..40 {
            for b in (a + 1)..40 {
                if ds.y[a] == ds.y[b] {
                    same.push(dist(a, b) as f32);
                } else {
                    diff.push(dist(a, b) as f32);
                }
            }
        }
        assert!(math::mean(&same) < math::mean(&diff));
    }

    #[test]
    fn unlabeled_generator_shapes() {
        let mut rng = Pcg64::new(5);
        let split = generate_unlabeled(50, 10, 512, &mut rng);
        assert_eq!(split.train.x_f32.len(), 50 * 512);
        assert_eq!(split.train.classes, 0);
    }
}
