//! TOML-subset parser (no `toml`/`serde` crates offline).
//!
//! Supports the fragment real experiment configs need:
//!   * `[table]` and `[dotted.table]` headers
//!   * `key = value` with string / integer / float / bool / array values
//!   * `#` comments, blank lines
//!
//! Not supported (rejected with a clear error, never silently): inline
//! tables, multi-line strings, dates, array-of-tables.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Float accessor that also accepts integers (TOML `0` for `0.0`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: flat map of `table.key` → value ("" table = root).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(src: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut table = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(fmt_err(lineno, "array-of-tables not supported"));
                }
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| fmt_err(lineno, "unclosed table header"))?
                    .trim();
                if name.is_empty() {
                    return Err(fmt_err(lineno, "empty table name"));
                }
                table = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| fmt_err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(fmt_err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| fmt_err(lineno, &e))?;
            let full = if table.is_empty() {
                key.to_string()
            } else {
                format!("{table}.{key}")
            };
            if doc.entries.insert(full.clone(), value).is_some() {
                return Err(fmt_err(lineno, &format!("duplicate key {full:?}")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Required accessor with a descriptive error.
    pub fn require(&self, key: &str) -> Result<&Value, String> {
        self.get(key).ok_or_else(|| format!("missing required config key {key:?}"))
    }

    /// All keys under a table prefix (e.g. `sampler.`).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        self.entries.keys().filter_map(move |k| k.strip_prefix(prefix))
    }
}

fn fmt_err(lineno: usize, msg: &str) -> String {
    format!("toml parse error on line {}: {msg}", lineno + 1)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quotes not supported".into());
        }
        return Ok(Value::Str(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, String> = split_top_level(inner)
            .into_iter()
            .map(|part| parse_value(part.trim()))
            .collect();
        return Ok(Value::Array(items?));
    }
    if s.contains('{') {
        return Err("inline tables not supported".into());
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split an array body on commas, respecting nested brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let d = Doc::parse("a = 1\nb = \"x\"\nc = true\nd = 2.5\n").unwrap();
        assert_eq!(d.get("a"), Some(&Value::Int(1)));
        assert_eq!(d.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(d.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("d").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn parses_tables_and_dotted() {
        let src = "[train]\nepochs = 10\n[sampler.es]\nbeta1 = 0.2\n";
        let d = Doc::parse(src).unwrap();
        assert_eq!(d.i64_or("train.epochs", 0), 10);
        assert_eq!(d.f64_or("sampler.es.beta1", 0.0), 0.2);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let src = "# header\na = 1 # trailing\n\nb = \"has # inside\"\n";
        let d = Doc::parse(src).unwrap();
        assert_eq!(d.i64_or("a", 0), 1);
        assert_eq!(d.get("b").unwrap().as_str(), Some("has # inside"));
    }

    #[test]
    fn arrays() {
        let d = Doc::parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nzs = []\n").unwrap();
        assert_eq!(d.get("xs").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(d.get("ys").unwrap().as_array().unwrap()[1].as_str(), Some("b"));
        assert!(d.get("zs").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn nested_arrays() {
        let d = Doc::parse("m = [[1, 2], [3, 4]]\n").unwrap();
        let outer = d.get("m").unwrap().as_array().unwrap();
        assert_eq!(outer[1].as_array().unwrap()[0], Value::Int(3));
    }

    #[test]
    fn errors_are_located() {
        let err = Doc::parse("a = 1\nbogus line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_duplicates_and_unsupported() {
        assert!(Doc::parse("a = 1\na = 2\n").unwrap_err().contains("duplicate"));
        assert!(Doc::parse("[[t]]\n").unwrap_err().contains("array-of-tables"));
        assert!(Doc::parse("x = {a = 1}\n").unwrap_err().contains("inline"));
    }

    #[test]
    fn int_float_distinction() {
        let d = Doc::parse("i = 3\nf = 3.0\ne = 1e3\nu = 1_000\n").unwrap();
        assert_eq!(d.get("i"), Some(&Value::Int(3)));
        assert_eq!(d.get("f"), Some(&Value::Float(3.0)));
        assert_eq!(d.get("e"), Some(&Value::Float(1000.0)));
        assert_eq!(d.get("u"), Some(&Value::Int(1000)));
        // as_f64 accepts ints too
        assert_eq!(d.get("i").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn negative_numbers() {
        let d = Doc::parse("a = -5\nb = -0.25\n").unwrap();
        assert_eq!(d.i64_or("a", 0), -5);
        assert_eq!(d.f64_or("b", 0.0), -0.25);
    }
}
